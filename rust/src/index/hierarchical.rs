//! Hierarchical (cascaded) partitioning — the paper's conclusion names
//! "cascading the process using hierarchical partitioning" as the
//! natural extension; this module implements the two-level version.
//!
//! Level 1 groups the `q` classes into `s` *super-classes* of `q/s`
//! classes each and stores one associative memory per super-class (the
//! merge of its classes' memories — the sum rule is additive, so the
//! super-memory is exactly `Σ_classes W_i`).  A query first polls the `s`
//! super-memories (`d²·s`), descends into the best `p₁`, polls only the
//! classes inside them (`d²·p₁·(q/s)`), and scans the best `p₂` classes.
//!
//! Scoring cost drops from `d²·q` to `d²·(s + p₁·q/s)` — minimized at
//! `s ≈ √(p₁·q)` — at the price of an extra miss opportunity; the
//! `ablation_hierarchical` figure quantifies the trade-off.

use crate::data::dataset::Dataset;
use crate::data::rng::Rng;
use crate::error::{Error, Result};
use crate::memory::{MemoryBank, StorageRule};
use crate::metrics::OpsCounter;
use crate::search::{distance_pruned, top_p_largest, TopK};

use super::am_index::{AmIndex, QueryResult};
use super::params::IndexParams;

/// Two-level cascaded index.
#[derive(Debug, Clone)]
pub struct HierarchicalIndex {
    /// The flat index (level 2: per-class memories + data).
    inner: AmIndex,
    /// Level-1 super-class memories, stacked `[s, d, d]`.
    super_bank: MemoryBank,
    /// `super_of[class] = super-class index`.
    super_of: Vec<u32>,
    /// Classes inside each super-class.
    classes_of: Vec<Vec<u32>>,
}

impl HierarchicalIndex {
    /// Build: flat index first, then merge consecutive classes into `s`
    /// super-classes.
    pub fn build(
        data: Dataset,
        params: IndexParams,
        n_super: usize,
        rng: &mut Rng,
    ) -> Result<Self> {
        if params.rule != StorageRule::Sum {
            return Err(Error::Config(
                "hierarchical cascade requires the sum rule (memories must be additive)"
                    .into(),
            ));
        }
        let q = params.n_classes;
        if n_super == 0 || n_super > q {
            return Err(Error::Config(format!(
                "need 1 <= n_super={n_super} <= q={q}"
            )));
        }
        let inner = AmIndex::build(data, params, rng)?;
        let dim = inner.dim();
        let per = q.div_ceil(n_super);
        let mut super_of = vec![0u32; q];
        let mut classes_of = vec![Vec::new(); n_super];
        for c in 0..q {
            let s = (c / per).min(n_super - 1);
            super_of[c] = s as u32;
            classes_of[s].push(c as u32);
        }
        // super-memory = sum of member class memories (sum rule additive)
        let sz = dim * dim;
        let mut weights = vec![0f32; n_super * sz];
        let mut counts = vec![0usize; n_super];
        for c in 0..q {
            let s = super_of[c] as usize;
            let src = inner.bank().class_weights(c);
            let dst = &mut weights[s * sz..(s + 1) * sz];
            for (a, b) in dst.iter_mut().zip(src) {
                *a += b;
            }
            counts[s] += inner.bank().count(c);
        }
        let super_bank =
            MemoryBank::from_parts(dim, weights, counts, StorageRule::Sum)?;
        Ok(HierarchicalIndex { inner, super_bank, super_of, classes_of })
    }

    /// The flat level-2 index.
    pub fn inner(&self) -> &AmIndex {
        &self.inner
    }

    /// Number of super-classes `s`.
    pub fn n_super(&self) -> usize {
        self.classes_of.len()
    }

    /// Super-class of class `c`.
    pub fn super_of(&self, c: usize) -> u32 {
        self.super_of[c]
    }

    /// Online insert: forward to the flat index, then additively update
    /// the affected super-class memory (the sum rule makes the
    /// super-memory exactly `Σ_classes W_i`, so one
    /// [`MemoryBank::add_to_class`] keeps the cascade consistent).
    /// Returns the new vector id.
    pub fn insert(&mut self, x: &[f32]) -> Result<u32> {
        let id = self.inner.insert(x)?;
        let class = self.inner.partition().class_of(id as usize) as usize;
        let s = self.super_of[class] as usize;
        self.super_bank.add_to_class(s, x);
        Ok(id)
    }

    /// 1-NN query through the cascade (see [`Self::query_k`]).
    pub fn query(
        &self,
        x: &[f32],
        p1: usize,
        p2: usize,
        ops: &mut OpsCounter,
    ) -> QueryResult {
        self.query_k(x, p1, p2, 1, ops)
    }

    /// k-NN query through the cascade: poll `s` super-memories, descend
    /// into the top `p1`, poll their classes, scan the top `p2` classes
    /// with a fused `TopK(k)` accumulator.
    pub fn query_k(
        &self,
        x: &[f32],
        p1: usize,
        p2: usize,
        k: usize,
        ops: &mut OpsCounter,
    ) -> QueryResult {
        let d = self.inner.dim();
        // level 1
        let super_scores = self.super_bank.score_query(x);
        ops.score_ops += (d * d * self.n_super()) as u64;
        let top_super = top_p_largest(&super_scores, p1.max(1));
        // level 2: only classes inside the selected super-classes
        let mut cand_classes: Vec<u32> = Vec::new();
        for &s in &top_super {
            cand_classes.extend_from_slice(&self.classes_of[s as usize]);
        }
        let class_scores: Vec<f32> = cand_classes
            .iter()
            .map(|&c| {
                let w = self.inner.bank().class_weights(c as usize);
                let mut total = 0f32;
                for (l, &xl) in x.iter().enumerate() {
                    if xl == 0.0 {
                        continue;
                    }
                    let row = &w[l * d..(l + 1) * d];
                    let mut acc = 0f32;
                    for (wm, &xm) in row.iter().zip(x) {
                        acc += wm * xm;
                    }
                    total += xl * acc;
                }
                total
            })
            .collect();
        ops.score_ops += (d * d * cand_classes.len()) as u64;
        let order = top_p_largest(&class_scores, p2.max(1).min(cand_classes.len()));
        let polled: Vec<u32> = order.iter().map(|&i| cand_classes[i as usize]).collect();
        // scan: fused TopK(k) with early abandoning, the same selection
        // rule as the flat index's candidate scan
        let metric = self.inner.params().metric;
        let mut acc = TopK::new(k.max(1));
        let mut candidates = 0usize;
        for &ci in &polled {
            for &vid in self.inner.partition().members(ci as usize) {
                candidates += 1;
                if let Some(dist) = distance_pruned(
                    metric,
                    x,
                    self.inner.data().get(vid as usize),
                    acc.bound(),
                ) {
                    acc.push(dist, vid);
                }
            }
        }
        ops.scan_ops += (candidates * d) as u64;
        ops.searches += 1;
        QueryResult { neighbors: acc.into_neighbors(), polled, candidates }
    }

    /// Scoring cost of this cascade at depth `p1` (the flat cost is
    /// `d²·q`): `d²·(s + p1·ceil(q/s))`.
    pub fn scoring_cost(&self, p1: usize) -> u64 {
        let d = self.inner.dim() as u64;
        let per = self.inner.params().n_classes.div_ceil(self.n_super()) as u64;
        d * d * (self.n_super() as u64 + p1 as u64 * per)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{self, QueryModel};

    fn workload(seed: u64) -> crate::data::Workload {
        let mut rng = Rng::new(seed);
        synthetic::dense_workload(64, 1024, 50, QueryModel::Exact, &mut rng)
    }

    #[test]
    fn build_shapes() {
        let wl = workload(1);
        let mut rng = Rng::new(2);
        let params = IndexParams { n_classes: 16, ..Default::default() };
        let h = HierarchicalIndex::build(wl.base.clone(), params, 4, &mut rng).unwrap();
        assert_eq!(h.n_super(), 4);
        for c in 0..16 {
            assert_eq!(h.super_of(c), (c / 4) as u32);
        }
    }

    #[test]
    fn super_memory_is_sum_of_members() {
        let wl = workload(3);
        let mut rng = Rng::new(4);
        let params = IndexParams { n_classes: 8, ..Default::default() };
        let h = HierarchicalIndex::build(wl.base.clone(), params, 2, &mut rng).unwrap();
        let d = h.inner().dim();
        for s in 0..2 {
            let sw = h.super_bank.class_weights(s);
            let mut sum = vec![0f32; d * d];
            for c in (s * 4)..(s * 4 + 4) {
                for (a, b) in sum.iter_mut().zip(h.inner().bank().class_weights(c)) {
                    *a += b;
                }
            }
            for (a, b) in sw.iter().zip(&sum) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn full_cascade_poll_is_exact() {
        let wl = workload(5);
        let mut rng = Rng::new(6);
        let params = IndexParams { n_classes: 16, ..Default::default() };
        let h = HierarchicalIndex::build(wl.base.clone(), params, 4, &mut rng).unwrap();
        let mut ops = OpsCounter::new();
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let r = h.query(wl.queries.get(qi), 4, 16, &mut ops);
            assert_eq!(r.id(), gt, "query {qi}");
        }
    }

    #[test]
    fn cascade_scores_cheaper_than_flat() {
        let wl = workload(7);
        let mut rng = Rng::new(8);
        let params = IndexParams { n_classes: 64, ..Default::default() };
        let h = HierarchicalIndex::build(wl.base.clone(), params, 8, &mut rng).unwrap();
        // flat: d² * 64; cascade at p1=2: d² * (8 + 2*8) = d² * 24
        let flat = (64 * 64 * 64) as u64;
        assert!(h.scoring_cost(2) < flat);
        let mut ops = OpsCounter::new();
        h.query(wl.queries.get(0), 2, 2, &mut ops);
        assert_eq!(ops.score_ops, h.scoring_cost(2));
    }

    #[test]
    fn cascade_recall_reasonable_at_shallow_poll() {
        let wl = workload(9);
        let mut rng = Rng::new(10);
        let params = IndexParams { n_classes: 16, ..Default::default() };
        let h = HierarchicalIndex::build(wl.base.clone(), params, 4, &mut rng).unwrap();
        let mut ops = OpsCounter::new();
        let mut hits = 0;
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let r = h.query(wl.queries.get(qi), 2, 2, &mut ops);
            if r.id() == gt {
                hits += 1;
            }
        }
        assert!(hits >= 30, "hits={hits}/50");
    }

    #[test]
    fn query_k_full_cascade_matches_flat_topk() {
        let wl = workload(15);
        let mut rng = Rng::new(16);
        let params = IndexParams { n_classes: 8, ..Default::default() };
        let h = HierarchicalIndex::build(wl.base.clone(), params, 2, &mut rng).unwrap();
        let mut ops = OpsCounter::new();
        for qi in 0..10 {
            let x = wl.queries.get(qi);
            // full cascade poll scans every vector: the top-k must match
            // the flat index's full-poll top-k exactly
            let hk = h.query_k(x, 2, 8, 5, &mut ops);
            let fk = h.inner().query_k(x, 8, 5, &mut ops);
            assert_eq!(hk.neighbors, fk.neighbors, "query {qi}");
            assert_eq!(hk.candidates, wl.base.len());
        }
    }

    #[test]
    fn insert_updates_cascade_and_is_searchable() {
        let wl = workload(17);
        let mut rng = Rng::new(18);
        let params = IndexParams { n_classes: 8, ..Default::default() };
        let mut h =
            HierarchicalIndex::build(wl.base.clone(), params, 2, &mut rng).unwrap();
        let d = h.inner().dim();
        let v: Vec<f32> =
            (0..d).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let id = h.insert(&v).unwrap();
        assert_eq!(id as usize, wl.base.len());
        // the super-memory of the affected class is still the exact sum
        // of its member class memories (the sum-rule invariant)
        let sz = d * d;
        for s in 0..2 {
            let sw = h.super_bank.class_weights(s);
            let mut sum = vec![0f32; sz];
            for c in (s * 4)..(s * 4 + 4) {
                for (a, b) in sum.iter_mut().zip(h.inner().bank().class_weights(c)) {
                    *a += b;
                }
            }
            for (a, b) in sw.iter().zip(&sum) {
                assert!((a - b).abs() < 1e-2, "super {s}: {a} vs {b}");
            }
        }
        // a full cascade poll must find the inserted vector as its own NN
        let mut ops = OpsCounter::new();
        let r = h.query(&v, 2, 8, &mut ops);
        assert_eq!(r.id(), id);
        assert_eq!(r.distance(), 0.0);
        // repeated inserts stay consistent (partition + data + cascade)
        for _ in 0..5 {
            let w: Vec<f32> =
                (0..d).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
            let wid = h.insert(&w).unwrap();
            let r = h.query(&w, 2, 8, &mut ops);
            assert_eq!(r.id(), wid);
        }
        h.inner().partition().validate().unwrap();
    }

    #[test]
    fn max_rule_rejected() {
        let wl = workload(11);
        let mut rng = Rng::new(12);
        let params = IndexParams {
            n_classes: 8,
            rule: StorageRule::Max,
            ..Default::default()
        };
        assert!(
            HierarchicalIndex::build(wl.base.clone(), params, 2, &mut rng).is_err()
        );
    }

    #[test]
    fn bad_n_super_rejected() {
        let wl = workload(13);
        let mut rng = Rng::new(14);
        let params = IndexParams { n_classes: 8, ..Default::default() };
        assert!(HierarchicalIndex::build(wl.base.clone(), params, 0, &mut rng).is_err());
        assert!(HierarchicalIndex::build(wl.base.clone(), params, 9, &mut rng).is_err());
    }
}
