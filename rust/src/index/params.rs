//! Index hyper-parameters.

use crate::error::{Error, Result};
use crate::memory::StorageRule;
use crate::partition::Allocation;
use crate::quant::ScanPrecision;
use crate::search::Metric;

/// Parameters of an associative-memory ANN index.
#[derive(Debug, Clone, Copy)]
pub struct IndexParams {
    /// Number of classes `q`.
    pub n_classes: usize,
    /// Default number of classes polled per query (`p`, overridable per
    /// request).
    pub top_p: usize,
    /// Default number of nearest neighbors returned per query (`k`,
    /// overridable per request; clamped to the database size at query
    /// time).
    pub top_k: usize,
    /// Memory storage rule (sum = paper's analyzed rule, max = [19]).
    pub rule: StorageRule,
    /// How vectors are allocated to classes.
    pub allocation: Allocation,
    /// Distance metric of the final candidate scan.
    pub metric: Metric,
    /// Cap on class size for greedy allocation, as a multiple of the
    /// mean size `n/q` (None = unbounded).
    pub greedy_cap_factor: Option<f64>,
    /// Candidate-scan precision: exact f32, or a compressed scan
    /// (SQ8 / PQ) with exact rerank (see [`crate::quant`]).
    pub precision: ScanPrecision,
}

impl Default for IndexParams {
    fn default() -> Self {
        IndexParams {
            n_classes: 64,
            top_p: 1,
            top_k: 1,
            rule: StorageRule::Sum,
            allocation: Allocation::Random,
            metric: Metric::SqL2,
            greedy_cap_factor: None,
            precision: ScanPrecision::Exact,
        }
    }
}

impl IndexParams {
    /// Validate against a database of `n` vectors.
    pub fn validate(&self, n: usize) -> Result<()> {
        if self.n_classes == 0 {
            return Err(Error::Config("n_classes must be > 0".into()));
        }
        if self.n_classes > n {
            return Err(Error::Config(format!(
                "n_classes {} > n {}",
                self.n_classes, n
            )));
        }
        if self.top_p == 0 || self.top_p > self.n_classes {
            return Err(Error::Config(format!(
                "top_p {} must be in 1..={}",
                self.top_p, self.n_classes
            )));
        }
        if self.top_k == 0 {
            return Err(Error::Config("top_k must be > 0".into()));
        }
        if let Some(f) = self.greedy_cap_factor {
            if f < 1.0 {
                return Err(Error::Config(format!(
                    "greedy_cap_factor {f} must be >= 1"
                )));
            }
        }
        self.precision.validate_params()?;
        if self.precision != ScanPrecision::Exact && self.metric != Metric::SqL2 {
            return Err(Error::Config(format!(
                "quantized scan precision {} requires the sq_l2 metric \
                 (the compressed kernels approximate squared L2)",
                self.precision
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        IndexParams::default().validate(1000).unwrap();
    }

    #[test]
    fn rejects_bad() {
        let mut p = IndexParams::default();
        p.n_classes = 0;
        assert!(p.validate(10).is_err());
        p.n_classes = 20;
        assert!(p.validate(10).is_err());
        p.n_classes = 4;
        p.top_p = 5;
        assert!(p.validate(10).is_err());
        p.top_p = 1;
        p.greedy_cap_factor = Some(0.5);
        assert!(p.validate(10).is_err());
        p.greedy_cap_factor = None;
        p.top_k = 0;
        assert!(p.validate(10).is_err());
    }

    #[test]
    fn quantized_precision_requires_sq_l2() {
        let p = IndexParams {
            precision: ScanPrecision::Sq8 { rerank: 8 },
            ..Default::default()
        };
        p.validate(1000).unwrap();
        let p = IndexParams {
            precision: ScanPrecision::Sq8 { rerank: 8 },
            metric: Metric::NegDot,
            ..Default::default()
        };
        assert!(p.validate(1000).is_err());
        let p = IndexParams {
            precision: ScanPrecision::Pq { m: 4, bits: 9, rerank: 0 },
            ..Default::default()
        };
        assert!(p.validate(1000).is_err(), "bits out of range");
    }

    #[test]
    fn validate_accepts_edge_values() {
        let p = IndexParams { n_classes: 10, top_p: 10, ..Default::default() };
        p.validate(10).unwrap();
        let p = IndexParams {
            greedy_cap_factor: Some(1.0),
            n_classes: 2,
            ..Default::default()
        };
        p.validate(10).unwrap();
    }
}
