//! The associative-memory ANN index (the paper's system contribution).

pub mod am_index;
pub mod hierarchical;
pub mod params;
pub mod persist;

pub use am_index::{AmIndex, PoolingIndex, PoolingResult, QueryResult};
pub use hierarchical::HierarchicalIndex;
pub use params::IndexParams;
