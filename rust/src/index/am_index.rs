//! The associative-memory ANN index — the paper's system.
//!
//! Build: allocate the database into `q` classes (random / greedy /
//! round-robin), build one sum- or max-rule memory per class, stack them
//! into a [`MemoryBank`].
//!
//! Query: score all `q` memories with the bilinear form (natively here;
//! the PJRT path in [`crate::runtime`] produces identical scores), keep
//! the top-`p` classes, exhaustively scan their members with a fused
//! `TopK(k)` accumulator, return the `k` nearest candidates.  Every step
//! feeds the paper's [`OpsCounter`] cost model.

use crate::data::dataset::Dataset;
use crate::data::rng::Rng;
use crate::error::Result;
use crate::memory::{score as mem_score, MemoryBank};
use crate::metrics::OpsCounter;
use crate::partition::{greedy_alloc, random_alloc, roundrobin, Allocation, Partition};
use crate::quant::{effective_rerank, rerank::rerank_exact, IndexFootprint, QuantIndex};
use crate::search::{invert_polled, top_p_largest, Kernels, Neighbor, TopK};
use crate::store::{PagedStore, RowReader, Store, StoreStats};
use crate::util::par::parallel_map;

use super::params::IndexParams;

/// Result of a single query.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryResult {
    /// The k nearest candidates found, sorted ascending by
    /// `(distance, id)`.  Empty when no candidate was scanned (every
    /// polled class was empty); shorter than the requested `k` when fewer
    /// candidates exist.
    pub neighbors: Vec<Neighbor>,
    /// The classes that were polled, best score first.
    pub polled: Vec<u32>,
    /// Number of candidate vectors scanned.
    pub candidates: usize,
}

impl QueryResult {
    /// The single best candidate, if any was scanned.
    pub fn best(&self) -> Option<&Neighbor> {
        self.neighbors.first()
    }

    /// Database id of the best candidate (`u32::MAX` when no candidate
    /// was scanned — the historical sentinel, kept for the k = 1 view).
    pub fn id(&self) -> u32 {
        self.best().map_or(u32::MAX, |n| n.id)
    }

    /// Distance of the best candidate (`f32::INFINITY` when no candidate
    /// was scanned).
    pub fn distance(&self) -> f32 {
        self.best().map_or(f32::INFINITY, |n| n.distance)
    }
}

/// Built associative-memory index.
#[derive(Debug, Clone)]
pub struct AmIndex {
    params: IndexParams,
    partition: Partition,
    bank: MemoryBank,
    /// Owned copy of the database (the candidate scan needs raw vectors).
    data: Dataset,
    /// True when every stored vector is binary 0/1 (enables the paper's
    /// c²-cost sparse scoring).
    binary_sparse: bool,
    /// Compressed scan companion (codes + quantizer) when
    /// `params.precision != Exact`; the candidate scan then runs
    /// two-stage: approximate over codes, exact rerank of the best
    /// `rerank` survivors.
    quant: Option<QuantIndex>,
    /// Distance-kernel dispatch, selected once at build/load from CPU
    /// feature detection ([`Kernels::select`]); every distance the index
    /// computes goes through it, and STATS reports it as
    /// `kernel.backend`.
    kernels: Kernels,
    /// Where the exact f32 member rows live ([`crate::store`]): resident
    /// class-contiguous slabs (`slabs[ci]` = class `ci`'s rows in
    /// members-list order; empty when quantized — the code matrix
    /// already is class-addressable), or a paged store reading class
    /// extents from disk on demand.  Either way the batch scan streams
    /// class-major rows instead of chasing `data.get(vid)` through the
    /// global id order.
    store: Store,
}

/// Scan-tile budget: member rows are processed in tiles of at most this
/// many bytes (f32 rows or code rows), so a tile loaded for one batch
/// query is still L2-resident when the next query of the batch scans it.
/// 256 KiB fits comfortably inside the ≥ 1 MiB L2 of every deployment
/// target while leaving room for the queries and accumulators.
const SCAN_TILE_BYTES: usize = 256 * 1024;

/// Rows per scan tile for a `row_bytes`-wide representation (≥ 1, so
/// degenerate dimensions still make progress).
fn tile_rows(row_bytes: usize) -> usize {
    (SCAN_TILE_BYTES / row_bytes.max(1)).max(1)
}

/// The exact scan's class-contiguous slabs: one flat `[rows × d]` buffer
/// per class, rows in members-list order.  Skipped (empty) for quantized
/// indices, whose scan streams code rows instead.
fn member_slabs(
    n_classes: usize,
    partition: &Partition,
    data: &Dataset,
    quantized: bool,
) -> Vec<Vec<f32>> {
    if quantized {
        return Vec::new();
    }
    (0..n_classes)
        .map(|ci| {
            let members = partition.members(ci);
            let mut slab = Vec::with_capacity(members.len() * data.dim());
            for &vid in members {
                slab.extend_from_slice(data.get(vid as usize));
            }
            slab
        })
        .collect()
}

impl AmIndex {
    /// Build the index over `data`.
    pub fn build(data: Dataset, params: IndexParams, rng: &mut Rng) -> Result<Self> {
        params.validate(data.len())?;
        let q = params.n_classes;
        let partition = match params.allocation {
            Allocation::Random => random_alloc::allocate(data.len(), q, rng)?,
            Allocation::RoundRobin => roundrobin::allocate(data.len(), q)?,
            Allocation::Greedy => {
                let cap = params
                    .greedy_cap_factor
                    .map(|f| ((data.len() as f64 / q as f64) * f).ceil() as usize);
                greedy_alloc::allocate(
                    &data,
                    q,
                    greedy_alloc::GreedyOptions { max_size: cap },
                    rng,
                )?
            }
        };
        let member_bufs: Vec<Dataset> = (0..q)
            .map(|i| data.gather(partition.members(i)))
            .collect();
        let member_refs: Vec<&[f32]> =
            member_bufs.iter().map(|d| d.as_flat()).collect();
        let bank = MemoryBank::build(data.dim(), &member_refs, params.rule)?;
        let binary_sparse = data.is_binary_sparse();
        let quant = QuantIndex::train(&data, params.precision)?;
        let kernels = Kernels::select();
        let store =
            Store::resident(member_slabs(q, &partition, &data, quant.is_some()));
        Ok(AmIndex { params, partition, bank, data, binary_sparse, quant, kernels, store })
    }

    /// Reassemble an index from persisted parts (see [`super::persist`]).
    /// When the params request a quantized scan, the quantizer is
    /// retrained deterministically over `data` (identical to the one a
    /// fresh build would produce); [`Self::from_parts_with_quant`] skips
    /// the retraining by injecting persisted codes.
    pub fn from_parts(
        params: IndexParams,
        assignments: Vec<u32>,
        stacked: Vec<f32>,
        counts: Vec<usize>,
        data: Dataset,
    ) -> Result<Self> {
        let quant = QuantIndex::train(&data, params.precision)?;
        Self::from_parts_with_quant(params, assignments, stacked, counts, data, quant)
    }

    /// [`Self::from_parts`] with a prebuilt compressed companion (the
    /// persisted-index load path: codebooks and codes come from the v4
    /// artifact instead of being retrained).
    pub fn from_parts_with_quant(
        params: IndexParams,
        assignments: Vec<u32>,
        stacked: Vec<f32>,
        counts: Vec<usize>,
        data: Dataset,
        quant: Option<QuantIndex>,
    ) -> Result<Self> {
        params.validate(data.len())?;
        params.precision.validate_for_dim(data.dim())?;
        if let Some(q) = &quant {
            if q.len() != data.len() {
                return Err(crate::error::Error::Data(format!(
                    "{} quant code rows for {} vectors",
                    q.len(),
                    data.len()
                )));
            }
        }
        let partition = Partition::from_assignments(assignments, params.n_classes)?;
        partition.validate()?;
        let bank = crate::memory::MemoryBank::from_parts(
            data.dim(),
            stacked,
            counts,
            params.rule,
        )?;
        let binary_sparse = data.is_binary_sparse();
        let kernels = Kernels::select();
        let store = Store::resident(member_slabs(
            params.n_classes,
            &partition,
            &data,
            quant.is_some(),
        ));
        Ok(AmIndex { params, partition, bank, data, binary_sparse, quant, kernels, store })
    }

    /// Reassemble an index whose exact member rows stay on disk behind
    /// `paged` (the v5 paged load path, [`super::persist::load_paged`]).
    /// The in-RAM dataset is empty; every exact row the scan or rerank
    /// needs streams through the paged store's extent cache.
    /// `binary_sparse` comes from the artifact's flags byte — it cannot
    /// be derived from an empty dataset.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts_paged(
        params: IndexParams,
        assignments: Vec<u32>,
        stacked: Vec<f32>,
        counts: Vec<usize>,
        dim: usize,
        binary_sparse: bool,
        quant: Option<QuantIndex>,
        paged: PagedStore,
    ) -> Result<Self> {
        let n = assignments.len();
        params.validate(n)?;
        params.precision.validate_for_dim(dim)?;
        if let Some(q) = &quant {
            if q.len() != n {
                return Err(crate::error::Error::Data(format!(
                    "{} quant code rows for {n} vectors",
                    q.len()
                )));
            }
        }
        if paged.dim() != dim {
            return Err(crate::error::Error::Shape(format!(
                "paged store dim {} != index dim {dim}",
                paged.dim()
            )));
        }
        let partition = Partition::from_assignments(assignments, params.n_classes)?;
        partition.validate()?;
        let bank =
            crate::memory::MemoryBank::from_parts(dim, stacked, counts, params.rule)?;
        let kernels = Kernels::select();
        Ok(AmIndex {
            params,
            partition,
            bank,
            data: Dataset::empty(dim),
            binary_sparse,
            quant,
            kernels,
            store: Store::Paged(paged),
        })
    }

    /// Online insert: add a vector to the index without rebuilding.
    ///
    /// The class is chosen per the index's allocation strategy: greedy
    /// indices use the paper's normalized-score rule; random /
    /// round-robin indices place the vector in the currently smallest
    /// class (keeping the equal-size model).  Returns the new vector id.
    pub fn insert(&mut self, x: &[f32]) -> Result<u32> {
        if x.len() != self.dim() {
            return Err(crate::error::Error::Shape(format!(
                "vector dim {} != index dim {}",
                x.len(),
                self.dim()
            )));
        }
        if self.store.is_paged() {
            return Err(crate::error::Error::Config(
                "online insert requires a resident store: paged indices are \
                 read-only (load the index resident, insert, then re-save)"
                    .into(),
            ));
        }
        let class = match self.params.allocation {
            Allocation::Greedy => {
                let scores = self.bank.score_query(x);
                let mut best = 0usize;
                let mut best_score = f64::NEG_INFINITY;
                for (i, &s) in scores.iter().enumerate() {
                    let norm = s as f64 / self.bank.count(i).max(1) as f64;
                    if norm > best_score {
                        best_score = norm;
                        best = i;
                    }
                }
                best
            }
            _ => {
                // smallest class first (preserves the equal-size model)
                (0..self.params.n_classes)
                    .min_by_key(|&i| self.partition.members(i).len())
                    .unwrap_or(0)
            }
        };
        if self.binary_sparse && !x.iter().all(|&v| v == 0.0 || v == 1.0) {
            self.binary_sparse = false; // sparse fast path no longer valid
        }
        self.bank.add_to_class(class, x);
        let id = self.partition.push(class as u32)?;
        self.data.push(x)?;
        if let Store::Resident { slabs } = &mut self.store {
            if let Some(slab) = slabs.get_mut(class) {
                // the exact scan's slab mirrors the members list, which
                // appends the new id at the end of its class
                slab.extend_from_slice(x);
            }
        }
        if let Some(q) = &mut self.quant {
            // encode with the existing quantizer (codebooks are not
            // retrained online; out-of-range values clamp, and the
            // exact rerank stage keeps answers correct regardless)
            q.push(x);
        }
        Ok(id)
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        self.data.dim()
    }

    /// Database size `n` (partition-derived, so it holds for paged
    /// indices whose in-RAM dataset is empty).
    pub fn len(&self) -> usize {
        self.partition.n_vectors()
    }

    /// True when the index holds no vectors.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Index parameters.
    pub fn params(&self) -> &IndexParams {
        &self.params
    }

    /// The class partition.
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// The stacked memory bank (the PJRT scorer's `[q,d,d]` operand).
    pub fn bank(&self) -> &MemoryBank {
        &self.bank
    }

    /// The stored database.  **Empty (zero rows) for a paged index** —
    /// exact rows then come from [`Self::store`] /
    /// [`Self::exhaustive_exact`] instead.
    pub fn data(&self) -> &Dataset {
        &self.data
    }

    /// The vector store behind the exact scan ([`crate::store`]).
    pub fn store(&self) -> &Store {
        &self.store
    }

    /// True when exact member rows are paged from disk.
    pub fn is_paged(&self) -> bool {
        self.store.is_paged()
    }

    /// The first store I/O or integrity failure, if any (always `None`
    /// for resident indices).  The scan paths stay infallible — a failed
    /// class yields zero candidates — so `Result`-bearing serving layers
    /// check this after a scan to fail the request instead of silently
    /// returning a partial answer.
    pub fn store_error(&self) -> Option<String> {
        self.store.error()
    }

    /// Accounting snapshot of the vector store (the STATS `store`
    /// object and the `amsearch_store_*` Prometheus families).
    pub fn store_stats(&self) -> StoreStats {
        match &self.store {
            Store::Resident { .. } => StoreStats {
                kind: "resident",
                bytes_resident: (self.len() * self.dim() * 4) as u64,
                ..StoreStats::default()
            },
            Store::Paged(p) => p.stats(),
        }
    }

    /// Row-granular exact reads for the rerank stage, backed by the
    /// dataset (resident) or the extent cache (paged).
    fn rows(&self) -> RowReader<'_> {
        match &self.store {
            Store::Resident { .. } => RowReader::Dataset(&self.data),
            Store::Paged(p) => RowReader::Paged(p),
        }
    }

    /// Exhaustive exact top-`k` over the whole database, bypassing the
    /// poll — the shadow-rerank / `explain --exact` reference path.  A
    /// resident index streams the dataset in vid order; a paged index
    /// streams class extents class-major (one sequential read per
    /// class).  Either order yields the same top-`k`: the `k` smallest
    /// under the total `(distance, id)` order are invariant to candidate
    /// order, and early-abandoned candidates provably cannot enter the
    /// top-`k`.
    pub fn exhaustive_exact(&self, x: &[f32], k: usize) -> Vec<Neighbor> {
        let metric = self.params.metric;
        let d = self.dim();
        let mut acc = TopK::new(k.max(1));
        match &self.store {
            Store::Paged(_) => {
                for ci in 0..self.params.n_classes {
                    let members = self.partition.members(ci);
                    let rows = self.store.class_rows(ci);
                    for (&vid, v) in members.iter().zip(rows.chunks_exact(d)) {
                        if let Some(dist) =
                            self.kernels.distance_pruned(metric, x, v, acc.bound())
                        {
                            acc.push(dist, vid);
                        }
                    }
                }
            }
            Store::Resident { .. } => {
                for (vid, v) in self.data.as_flat().chunks_exact(d).enumerate() {
                    if let Some(dist) =
                        self.kernels.distance_pruned(metric, x, v, acc.bound())
                    {
                        acc.push(dist, vid as u32);
                    }
                }
            }
        }
        acc.into_neighbors()
    }

    /// True when the sparse (support-based, c²-cost) scoring path is used.
    pub fn uses_sparse_scoring(&self) -> bool {
        self.binary_sparse
    }

    /// The compressed scan companion, when the index is quantized.
    pub fn quant(&self) -> Option<&QuantIndex> {
        self.quant.as_ref()
    }

    /// The distance-kernel dispatch handle selected at build/load.
    pub fn kernels(&self) -> Kernels {
        self.kernels
    }

    /// Name of the selected kernel backend — the `kernel.backend` STATS
    /// field ("scalar" | "sse2" | "avx2" | "neon").
    pub fn kernel_backend(&self) -> &'static str {
        self.kernels.backend_name()
    }

    /// Mode label of the candidate scan ("exact" | "sq8" | "pq") — the
    /// `quant.mode` STATS field.
    pub fn quant_mode(&self) -> &'static str {
        self.quant.as_ref().map_or("exact", |q| q.mode())
    }

    /// Change the rerank budget without retraining codebooks (evals and
    /// benches sweep this knob).  No-op on an exact index.
    pub fn set_scan_rerank(&mut self, rerank: usize) {
        self.params.precision = self.params.precision.with_rerank(rerank);
        if let Some(q) = &mut self.quant {
            q.set_rerank(rerank);
        }
    }

    /// Memory footprint of the candidate-scan representation: f32
    /// member-matrix bytes versus what the scan keeps resident (codes +
    /// codebooks for a quantized index).
    pub fn footprint(&self) -> IndexFootprint {
        let bytes = (self.len() * self.dim() * 4) as u64;
        IndexFootprint {
            bytes,
            compressed_bytes: self
                .quant
                .as_ref()
                .map_or(bytes, |q| q.compressed_bytes()),
        }
    }

    /// Score every class against `x` (native path), with cost accounting.
    pub fn score_classes(&self, x: &[f32], ops: &mut OpsCounter) -> Vec<f32> {
        let d = self.dim();
        let q = self.params.n_classes;
        if self.binary_sparse {
            let support: Vec<u32> = x
                .iter()
                .enumerate()
                .filter(|(_, &v)| v != 0.0)
                .map(|(i, _)| i as u32)
                .collect();
            ops.score_ops += (support.len() * support.len() * q) as u64;
            self.bank.score_query_support(&support)
        } else {
            ops.score_ops += (d * d * q) as u64;
            self.bank.score_query(x)
        }
    }

    /// Batched native scoring (mirrors the AOT `class_scores` artifact).
    pub fn score_classes_batch(&self, queries: &[f32], ops: &mut OpsCounter) -> Vec<f32> {
        let d = self.dim();
        let q = self.params.n_classes;
        let batch = queries.len() / d;
        ops.score_ops += (d * d * q * batch) as u64;
        mem_score::score_batch(self.bank.stacked(), queries, d, q, self.kernels)
    }

    /// Rank all classes by score, best first (used by the recall@p
    /// evaluation and by `query`).
    pub fn ranked_classes(&self, x: &[f32], ops: &mut OpsCounter) -> Vec<u32> {
        let scores = self.score_classes(x, ops);
        top_p_largest(&scores, scores.len())
    }

    /// Finish a query given precomputed class scores: select top-`p`
    /// classes, scan their members, return the `k` nearest candidates.
    pub fn finish_query(
        &self,
        x: &[f32],
        scores: &[f32],
        p: usize,
        k: usize,
        ops: &mut OpsCounter,
    ) -> QueryResult {
        let polled = top_p_largest(scores, p);
        let (neighbors, candidates) = self.scan_classes(x, &polled, k, ops);
        ops.searches += 1;
        QueryResult { neighbors, polled, candidates }
    }

    /// Finish a whole batch of queries given the batch's precomputed
    /// class scores: select top-`p` per query, then run the candidate
    /// scan **class-major** — the (query → polled classes) map is
    /// inverted into (class → querying batch members) and each polled
    /// class's member matrix is streamed exactly once for the whole
    /// batch, scoring every query that polled it (the same batch fusion
    /// [`crate::memory::score::score_batch`] applies to the scoring
    /// stage).  Classes are scanned in parallel; within a class each
    /// query keeps a fused `TopK(k)` accumulator whose early-abandon
    /// threshold is its current k-th best ([`TopK::bound`] feeding
    /// [`crate::search::distance_pruned`]); per-class accumulators are
    /// then merged into the per-query top-k.
    ///
    /// `scores` is `[B * q]` row-major; `ps[b]` is query `b`'s poll
    /// depth; `ks[b]` its neighbor count; `ops[b]` receives query `b`'s
    /// scan-stage accounting.
    ///
    /// Guaranteed bitwise-identical to `B` independent
    /// [`Self::finish_query`] calls at every `k`: polled order, candidate
    /// counts, op counts, and each reported neighbor's id and distance
    /// all match exactly (the batch restructuring changes memory access
    /// order, never arithmetic — the k smallest under the total
    /// `(distance, id)` order are invariant to candidate order, and
    /// abandoned candidates provably cannot enter any top-k; see
    /// `prop_finish_batch_matches_sequential`).
    pub fn finish_batch(
        &self,
        queries: &[&[f32]],
        scores: &[f32],
        ps: &[usize],
        ks: &[usize],
        ops: &mut [OpsCounter],
    ) -> Vec<QueryResult> {
        let q = self.params.n_classes;
        let b = queries.len();
        assert_eq!(scores.len(), b * q, "scores buffer must be [B * q]");
        assert_eq!(ps.len(), b, "one poll depth per query");
        assert_eq!(ks.len(), b, "one neighbor count per query");
        assert_eq!(ops.len(), b, "one ops counter per query");
        let polled: Vec<Vec<u32>> = (0..b)
            .map(|bi| top_p_largest(&scores[bi * q..(bi + 1) * q], ps[bi]))
            .collect();
        if let Some(quant) = &self.quant {
            return self.finish_batch_quant(quant, queries, polled, ks, ops);
        }
        // invert (query -> polled classes) into (class -> querying
        // batch members); only classes someone polled get scanned
        let by_class = invert_polled(&polled, q);
        let active: Vec<usize> =
            (0..q).filter(|&ci| !by_class[ci].is_empty()).collect();
        let metric = self.params.metric;
        let d = self.dim();
        let kernels = self.kernels;
        // one pass over each polled class's member slab, tiled to fit in
        // L2 so each tile of rows is reused across every querying batch
        // member before the next tile is streamed in; per (class, query)
        // a fused TopK(k) accumulator with early abandoning.  Within a
        // tile the loop is query-outer / row-inner, so each query still
        // sees candidates in ascending member order — the per-query
        // arithmetic and abandon decisions are unchanged from the
        // untiled scan (bitwise guarantee preserved)
        let scan_class = |ci: usize| -> Vec<(u32, TopK)> {
            let queriers = &by_class[ci];
            let mut accs: Vec<(u32, TopK)> = queriers
                .iter()
                .map(|&bi| (bi, TopK::new(ks[bi as usize].max(1))))
                .collect();
            let members = self.partition.members(ci);
            // resident: borrow the class slab; paged: one cache hit or
            // one sequential extent read serving the *whole batch* —
            // the class-major inversion is what coalesces reads across
            // every querying batch member
            let rows = self.store.class_rows(ci);
            let slab: &[f32] = &rows;
            let tr = tile_rows(d * 4);
            for (tile_members, tile_slab) in
                members.chunks(tr).zip(slab.chunks(tr * d))
            {
                for (qi, acc) in accs.iter_mut() {
                    let x = queries[*qi as usize];
                    for (&vid, v) in
                        tile_members.iter().zip(tile_slab.chunks_exact(d))
                    {
                        // abandon candidates that provably exceed this
                        // query's in-class k-th best; ties survive for
                        // the id tie-break
                        if let Some(dist) =
                            kernels.distance_pruned(metric, x, v, acc.bound())
                        {
                            acc.push(dist, vid);
                        }
                    }
                }
            }
            accs
        };
        // parallel over active classes (each d²-sized slab touched by
        // exactly one thread) — but only when the batch is big enough to
        // amortize thread spawns; a batch of one stays spawn-free like
        // the sequential path it replaces
        let class_accs: Vec<Vec<(u32, TopK)>> = if b <= 1 || active.len() <= 1 {
            active.iter().map(|&ci| scan_class(ci)).collect()
        } else {
            parallel_map(active.len(), |i| scan_class(active[i]))
        };
        // fold the per-class accumulators per query: the same total
        // (distance, id) selection rule as the sequential scan
        let mut best: Vec<TopK> =
            ks.iter().map(|&k| TopK::new(k.max(1))).collect();
        for accs in class_accs {
            for (bi, acc) in accs {
                best[bi as usize].merge(acc);
            }
        }
        let mut out = Vec::with_capacity(b);
        for ((bi, pol), acc) in polled.into_iter().enumerate().zip(best) {
            let candidates: usize = pol
                .iter()
                .map(|&ci| self.partition.members(ci as usize).len())
                .sum();
            let per_candidate = if self.binary_sparse {
                queries[bi].iter().filter(|&&v| v != 0.0).count()
            } else {
                self.dim()
            };
            ops[bi].scan_ops += (candidates * per_candidate) as u64;
            ops[bi].searches += 1;
            out.push(QueryResult {
                neighbors: acc.into_neighbors(),
                polled: pol,
                candidates,
            });
        }
        out
    }

    /// The class-major compressed scan of a whole batch: per-query ADC
    /// tables / SQ8 residuals are built **once per batch**
    /// ([`QuantIndex::prepare`]) and shared across every class a query
    /// polled; each polled class's *code* matrix is streamed exactly
    /// once for the batch (the same fusion as the exact class-major
    /// scan, over 4–16× fewer bytes), with per-(class, query)
    /// approximate `TopK(r)` accumulators merged per query and
    /// exact-reranked.
    ///
    /// Bitwise-identical to B independent [`Self::finish_query`] calls
    /// on the same quantized index: the approximate keys are computed by
    /// the same kernel in the same per-candidate term order, `TopK`
    /// selection and merging are invariant to candidate order under the
    /// total `(key, id)` order, so the survivor sets — and therefore the
    /// exact-reranked results and op counts — match exactly.
    fn finish_batch_quant(
        &self,
        quant: &QuantIndex,
        queries: &[&[f32]],
        polled: Vec<Vec<u32>>,
        ks: &[usize],
        ops: &mut [OpsCounter],
    ) -> Vec<QueryResult> {
        let q = self.params.n_classes;
        let b = queries.len();
        let by_class = invert_polled(&polled, q);
        let active: Vec<usize> =
            (0..q).filter(|&ci| !by_class[ci].is_empty()).collect();
        // per-query scan state, built once per batch: the LUT (ADC
        // table / residual), the candidate count, the rerank heap size
        let luts: Vec<crate::quant::QueryLut<'_>> =
            queries.iter().map(|x| quant.prepare(x, self.kernels)).collect();
        let candidates: Vec<usize> = polled
            .iter()
            .map(|pol| {
                pol.iter()
                    .map(|&ci| self.partition.members(ci as usize).len())
                    .sum()
            })
            .collect();
        let r_effs: Vec<usize> = (0..b)
            .map(|bi| effective_rerank(quant.rerank(), ks[bi].max(1), candidates[bi]))
            .collect();
        // stage 1, class-major: one pass over each polled class's code
        // rows, scoring every querying batch member via its shared LUT
        let scan_class = |ci: usize| -> Vec<(u32, TopK)> {
            let queriers = &by_class[ci];
            let mut accs: Vec<(u32, TopK)> = queriers
                .iter()
                .map(|&bi| (bi, TopK::new(r_effs[bi as usize])))
                .collect();
            // tile the member list so a tile's worth of code bytes stays
            // cache-resident across every querying batch member; within
            // a tile the loop is query-outer / code-inner, preserving
            // each query's ascending candidate order
            let members = self.partition.members(ci);
            let tr = tile_rows(quant.code_len());
            for tile_members in members.chunks(tr) {
                for (bi, acc) in accs.iter_mut() {
                    let lut = &luts[*bi as usize];
                    for &vid in tile_members {
                        if let Some(ad) =
                            lut.distance_pruned(quant.code(vid as usize), acc.bound())
                        {
                            acc.push(ad, vid);
                        }
                    }
                }
            }
            accs
        };
        let class_accs: Vec<Vec<(u32, TopK)>> = if b <= 1 || active.len() <= 1 {
            active.iter().map(|&ci| scan_class(ci)).collect()
        } else {
            parallel_map(active.len(), |i| scan_class(active[i]))
        };
        let mut survivors: Vec<TopK> =
            r_effs.iter().map(|&r| TopK::new(r)).collect();
        for accs in class_accs {
            for (bi, acc) in accs {
                survivors[bi as usize].merge(acc);
            }
        }
        // stage 2: exact rerank per query
        let mut out = Vec::with_capacity(b);
        for ((bi, pol), approx) in polled.into_iter().enumerate().zip(survivors) {
            let (neighbors, reranked) = rerank_exact(
                self.params.metric,
                queries[bi],
                self.rows(),
                approx.into_sorted(),
                ks[bi].max(1),
                self.kernels,
            );
            ops[bi].compressed_ops +=
                (candidates[bi] * quant.approx_unit_cost()) as u64;
            ops[bi].rerank_ops += (reranked * self.dim()) as u64;
            ops[bi].searches += 1;
            out.push(QueryResult { neighbors, polled: pol, candidates: candidates[bi] });
        }
        out
    }

    /// Exhaustive top-`k` scan over the members of the given classes: a
    /// single fused `TopK(k)` accumulator with threshold-based early
    /// abandoning (bitwise-identical distances for every kept candidate).
    /// On a quantized index this runs the two-stage compressed scan
    /// instead ([`Self::scan_classes_quant`]).
    fn scan_classes(
        &self,
        x: &[f32],
        classes: &[u32],
        k: usize,
        ops: &mut OpsCounter,
    ) -> (Vec<Neighbor>, usize) {
        if let Some(quant) = &self.quant {
            return self.scan_classes_quant(quant, x, classes, k, ops);
        }
        let metric = self.params.metric;
        let mut acc = TopK::new(k.max(1));
        let mut candidates = 0usize;
        // sparse scan cost is c per candidate (§5.2: pkc), dense is d
        let per_candidate = if self.binary_sparse {
            x.iter().filter(|&&v| v != 0.0).count()
        } else {
            self.dim()
        };
        let d = self.dim();
        for &ci in classes {
            // stream the class's contiguous member rows (ascending
            // member order, same as the members list) — resident slab
            // borrow or one paged extent fetch
            let members = self.partition.members(ci as usize);
            let rows = self.store.class_rows(ci as usize);
            let slab: &[f32] = &rows;
            candidates += members.len();
            for (&vid, v) in members.iter().zip(slab.chunks_exact(d)) {
                if let Some(dist) =
                    self.kernels.distance_pruned(metric, x, v, acc.bound())
                {
                    acc.push(dist, vid);
                }
            }
        }
        ops.scan_ops += (candidates * per_candidate) as u64;
        (acc.into_neighbors(), candidates)
    }

    /// The two-stage compressed scan of a quantized index: rank every
    /// member of the polled classes by approximate compressed distance
    /// (SQ8 integer kernel / PQ ADC lookups, early-abandoned against the
    /// current `r`-th best approximate key), then exact-rerank the best
    /// `r` survivors into the final top-`k`
    /// ([`crate::quant::rerank::rerank_exact`]).  With `rerank = 0`
    /// every scanned candidate survives, so the result is
    /// bitwise-identical to the exact scan.
    fn scan_classes_quant(
        &self,
        quant: &QuantIndex,
        x: &[f32],
        classes: &[u32],
        k: usize,
        ops: &mut OpsCounter,
    ) -> (Vec<Neighbor>, usize) {
        let lut = quant.prepare(x, self.kernels);
        let candidates: usize = classes
            .iter()
            .map(|&ci| self.partition.members(ci as usize).len())
            .sum();
        let r = effective_rerank(quant.rerank(), k.max(1), candidates);
        let mut approx = TopK::new(r);
        for &ci in classes {
            for &vid in self.partition.members(ci as usize) {
                if let Some(ad) =
                    lut.distance_pruned(quant.code(vid as usize), approx.bound())
                {
                    approx.push(ad, vid);
                }
            }
        }
        ops.compressed_ops += (candidates * quant.approx_unit_cost()) as u64;
        let (neighbors, reranked) = rerank_exact(
            self.params.metric,
            x,
            self.rows(),
            approx.into_sorted(),
            k.max(1),
            self.kernels,
        );
        ops.rerank_ops += (reranked * self.dim()) as u64;
        (neighbors, candidates)
    }

    /// Full 1-NN query: score, poll top-`p`, scan, with cost accounting.
    pub fn query(&self, x: &[f32], p: usize, ops: &mut OpsCounter) -> QueryResult {
        self.query_k(x, p, 1, ops)
    }

    /// Full k-NN query: score, poll top-`p`, scan keeping the `k`
    /// nearest, with cost accounting.
    pub fn query_k(
        &self,
        x: &[f32],
        p: usize,
        k: usize,
        ops: &mut OpsCounter,
    ) -> QueryResult {
        let scores = self.score_classes(x, ops);
        self.finish_query(x, &scores, p, k, ops)
    }

    /// Query with the index's default poll depth and neighbor count.
    pub fn query_default(&self, x: &[f32], ops: &mut OpsCounter) -> QueryResult {
        self.query_k(x, self.params.top_p, self.params.top_k, ops)
    }

    /// Adaptive query: the poll depth is chosen per query from the score
    /// distribution (paper conclusion: "improving the method further").
    pub fn query_adaptive(
        &self,
        x: &[f32],
        policy: &crate::search::AdaptivePolicy,
        ops: &mut OpsCounter,
    ) -> QueryResult {
        let scores = self.score_classes(x, ops);
        let p = policy.choose_p(&scores);
        self.finish_query(x, &scores, p, self.params.top_k, ops)
    }
}

/// Test-support fixture shared by the unit/integration suites: a
/// 4-class index over four 3-d binary vectors where classes 0 and 1 are
/// **empty** (assignments `[2, 3, 2, 3]`).  The probe `[0, 0, 1]` is
/// orthogonal to every stored vector, so all class scores tie at 0 and
/// top-2 selection polls exactly the two empty classes — the
/// "no candidates" edge case.
#[doc(hidden)]
pub fn two_empty_classes_fixture() -> AmIndex {
    let d = 3;
    let c2: Vec<f32> = vec![1., 0., 0., 1., 0., 0.];
    let c3: Vec<f32> = vec![0., 1., 0., 0., 1., 0.];
    let empty: Vec<f32> = Vec::new();
    let refs: [&[f32]; 4] =
        [empty.as_slice(), empty.as_slice(), c2.as_slice(), c3.as_slice()];
    let bank = MemoryBank::build(d, &refs, crate::memory::StorageRule::Sum)
        // amlint: allow(panic, reason = "test-support fixture over constant inputs; only reachable from test code")
        .expect("fixture bank");
    let data =
        Dataset::from_flat(d, vec![1., 0., 0., 0., 1., 0., 1., 0., 0., 0., 1., 0.])
            // amlint: allow(panic, reason = "test-support fixture over constant inputs; only reachable from test code")
            .expect("fixture data");
    let params = IndexParams { n_classes: 4, top_p: 2, ..Default::default() };
    AmIndex::from_parts(
        params,
        vec![2, 3, 2, 3],
        bank.stacked().to_vec(),
        vec![0, 0, 2, 2],
        data,
    )
    // amlint: allow(panic, reason = "test-support fixture over constant inputs; only reachable from test code")
    .expect("fixture index")
}

/// Pooling-retrieval wrapper — the paper's "smart pooling" future-work
/// idea: in the winning class, run a Hopfield readout on the class
/// memory (`d²` cost, independent of `k`) instead of scanning the `k`
/// members.  A successful readout that maps to a stored vector replaces
/// the scan; failures fall back to the exhaustive in-class scan.
#[derive(Debug, Clone)]
pub struct PoolingIndex {
    index: AmIndex,
    lookup: crate::memory::retrieval::PatternLookup,
    /// Expected support size for the sparse winner-take-all readout
    /// (ignored for dense data).
    sparse_c: usize,
}

/// Result of a pooling query, annotated with the path taken.
#[derive(Debug, Clone, PartialEq)]
pub struct PoolingResult {
    /// The answer (same contract as [`QueryResult`]).
    pub result: QueryResult,
    /// True when the Hopfield readout resolved the query (no scan).
    pub pooled: bool,
}

impl PoolingIndex {
    /// Wrap a built index.
    pub fn new(index: AmIndex) -> Self {
        let lookup = crate::memory::retrieval::PatternLookup::build(index.data());
        let sparse_c = if index.uses_sparse_scoring() {
            let n = index.len().max(1);
            let total: usize = (0..n.min(256))
                .map(|i| index.data().get(i).iter().filter(|&&v| v != 0.0).count())
                .sum();
            (total / n.min(256)).max(1)
        } else {
            0
        };
        PoolingIndex { index, lookup, sparse_c }
    }

    /// The wrapped index.
    pub fn index(&self) -> &AmIndex {
        &self.index
    }

    /// Query via readout on the top class; falls back to a top-`p` scan.
    pub fn query(&self, x: &[f32], p: usize, ops: &mut OpsCounter) -> PoolingResult {
        use crate::memory::retrieval::{readout_dense, readout_sparse};
        let scores = self.index.score_classes(x, ops);
        let ranked = top_p_largest(&scores, 1);
        let top = ranked[0] as usize;
        let d = self.index.dim();
        let w = self.index.bank().class_weights(top);
        let recovered = if self.index.uses_sparse_scoring() {
            let c = x.iter().filter(|&&v| v != 0.0).count().max(self.sparse_c);
            readout_sparse(w, x, d, c)
        } else {
            readout_dense(w, x, d)
        };
        ops.aux_ops += (d * d) as u64; // the readout field computation
        if let Some(id) = self.lookup.find(&recovered) {
            // verify the recovered pattern actually lives in the top class
            if self.index.partition().class_of(id as usize) == top as u32 {
                let distance = self.index.params().metric.distance(x, &recovered);
                ops.searches += 1;
                return PoolingResult {
                    result: QueryResult {
                        neighbors: vec![Neighbor { id, distance }],
                        polled: vec![top as u32],
                        candidates: 0,
                    },
                    pooled: true,
                };
            }
        }
        // fallback: standard scan (the readout is inherently 1-NN)
        let result = self.index.finish_query(x, &scores, p, 1, ops);
        PoolingResult { result, pooled: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{self, QueryModel, SparseSpec};

    fn dense_index(seed: u64, n: usize, q: usize) -> (AmIndex, crate::data::Workload) {
        let mut rng = Rng::new(seed);
        let wl = synthetic::dense_workload(64, n, 50, QueryModel::Exact, &mut rng);
        let params = IndexParams { n_classes: q, ..Default::default() };
        let idx = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        (idx, wl)
    }

    #[test]
    fn build_shapes() {
        let (idx, _) = dense_index(1, 256, 8);
        assert_eq!(idx.len(), 256);
        assert_eq!(idx.bank().n_classes(), 8);
        assert_eq!(idx.bank().stacked().len(), 8 * 64 * 64);
        assert!(!idx.uses_sparse_scoring());
    }

    #[test]
    fn exact_query_finds_itself_with_full_poll() {
        let (idx, wl) = dense_index(2, 128, 4);
        let mut ops = OpsCounter::new();
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            // p = q: scan everything; the stored copy must be found
            let r = idx.query(wl.queries.get(qi), 4, &mut ops);
            assert_eq!(r.id(), gt);
            assert_eq!(r.distance(), 0.0);
            assert_eq!(r.neighbors.len(), 1, "k=1 returns exactly one neighbor");
            assert_eq!(r.candidates, 128);
        }
    }

    #[test]
    fn query_k_returns_sorted_topk() {
        let (idx, wl) = dense_index(13, 128, 4);
        let mut ops = OpsCounter::new();
        for qi in 0..10 {
            let r = idx.query_k(wl.queries.get(qi), 4, 5, &mut ops);
            assert_eq!(r.neighbors.len(), 5);
            for w in r.neighbors.windows(2) {
                assert!(
                    w[0].distance < w[1].distance
                        || (w[0].distance == w[1].distance && w[0].id < w[1].id),
                    "neighbors not strictly (distance, id)-ascending: {:?}",
                    r.neighbors
                );
            }
            // the k=1 view of the k=5 result matches a k=1 query bitwise
            let r1 = idx.query(wl.queries.get(qi), 4, &mut ops);
            assert_eq!(r1.neighbors[0], r.neighbors[0]);
        }
        // k larger than the candidate set truncates to what exists
        let r = idx.query_k(wl.queries.get(0), 4, 1000, &mut ops);
        assert_eq!(r.neighbors.len(), 128);
    }

    #[test]
    fn top1_poll_mostly_correct_in_theory_regime() {
        // d=64, k=128 -> k in (d, d²); q small: error probability low
        let (idx, wl) = dense_index(3, 512, 4);
        let mut ops = OpsCounter::new();
        let mut hits = 0;
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let r = idx.query(wl.queries.get(qi), 1, &mut ops);
            if r.id() == gt {
                hits += 1;
            }
        }
        assert!(hits >= 40, "hits={hits}/50");
    }

    #[test]
    fn ops_accounting_matches_cost_model() {
        let (idx, wl) = dense_index(4, 256, 8);
        let mut ops = OpsCounter::new();
        let r = idx.query(wl.queries.get(0), 2, &mut ops);
        // dense: score = d² q
        assert_eq!(ops.score_ops, (64 * 64 * 8) as u64);
        // scan = candidates * d with candidates = 2 classes * 32
        assert_eq!(r.candidates, 64);
        assert_eq!(ops.scan_ops, (64 * 64) as u64);
        assert_eq!(ops.searches, 1);
    }

    #[test]
    fn sparse_index_uses_support_scoring() {
        let mut rng = Rng::new(5);
        let wl = synthetic::sparse_workload(
            SparseSpec { dim: 128, ones: 8.0 },
            200,
            10,
            QueryModel::Exact,
            &mut rng,
        );
        let params = IndexParams { n_classes: 5, ..Default::default() };
        let idx = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        assert!(idx.uses_sparse_scoring());
        let mut ops = OpsCounter::new();
        let q0 = wl.queries.get(0);
        let c = q0.iter().filter(|&&v| v != 0.0).count() as u64;
        idx.query(q0, 1, &mut ops);
        assert_eq!(ops.score_ops, c * c * 5);
    }

    #[test]
    fn ranked_classes_puts_gt_class_first_usually() {
        let (idx, wl) = dense_index(6, 512, 4);
        let mut ops = OpsCounter::new();
        let mut first = 0;
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let ranked = idx.ranked_classes(wl.queries.get(qi), &mut ops);
            assert_eq!(ranked.len(), 4);
            if ranked[0] == idx.partition().class_of(gt as usize) {
                first += 1;
            }
        }
        assert!(first >= 40, "first={first}/50");
    }

    #[test]
    fn batch_scores_match_single() {
        let (idx, wl) = dense_index(7, 128, 4);
        let mut ops = OpsCounter::new();
        let b = 5;
        let mut flat = Vec::new();
        for qi in 0..b {
            flat.extend_from_slice(wl.queries.get(qi));
        }
        let batch = idx.score_classes_batch(&flat, &mut ops);
        for qi in 0..b {
            let single = idx.score_classes(wl.queries.get(qi), &mut ops);
            for ci in 0..4 {
                assert!((batch[qi * 4 + ci] - single[ci]).abs() < 1e-2);
            }
        }
    }

    #[test]
    fn pooling_recovers_stored_patterns_without_scanning() {
        // low-load regime: k=16 patterns per class in d=256 (load 0.06,
        // well under the Hopfield one-step capacity)
        let mut rng = Rng::new(20);
        let wl = synthetic::dense_workload(
            256,
            64,
            40,
            QueryModel::Corrupted { alpha: 0.9 },
            &mut rng,
        );
        let params = IndexParams { n_classes: 4, ..Default::default() };
        let idx = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        let pool = PoolingIndex::new(idx);
        let mut ops = OpsCounter::new();
        let mut pooled_hits = 0;
        let mut total_pooled = 0;
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let r = pool.query(wl.queries.get(qi), 4, &mut ops);
            if r.pooled {
                total_pooled += 1;
                assert_eq!(r.result.candidates, 0, "pooled answers scan nothing");
                if r.result.id() == gt {
                    pooled_hits += 1;
                }
            }
        }
        assert!(total_pooled >= 30, "pooling path taken {total_pooled}/40");
        assert_eq!(pooled_hits, total_pooled, "pooled answers must be exact");
    }

    #[test]
    fn pooling_falls_back_on_hard_queries() {
        // overload: k=512 in d=32 — readout garbage, fallback must engage
        let mut rng = Rng::new(21);
        let wl = synthetic::dense_workload(32, 1024, 20, QueryModel::Exact, &mut rng);
        let params = IndexParams { n_classes: 2, ..Default::default() };
        let idx = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        let pool = PoolingIndex::new(idx);
        let mut ops = OpsCounter::new();
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let r = pool.query(wl.queries.get(qi), 2, &mut ops);
            // exact query + full poll fallback: answer always right
            // (either via an exact-match readout or the scan)
            assert_eq!(r.result.id(), gt, "query {qi}");
        }
    }

    #[test]
    fn adaptive_query_spends_less_on_easy_workloads() {
        let mut rng = Rng::new(22);
        let wl = synthetic::dense_workload(64, 512, 60, QueryModel::Exact, &mut rng);
        let params = IndexParams { n_classes: 8, ..Default::default() };
        let idx = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        let policy = crate::search::AdaptivePolicy { min_p: 1, max_p: 8, mass: 0.3 };
        let mut ops_adaptive = OpsCounter::new();
        let mut ops_fixed = OpsCounter::new();
        let mut hits = 0;
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let r = idx.query_adaptive(wl.queries.get(qi), &policy, &mut ops_adaptive);
            if r.id() == gt {
                hits += 1;
            }
            idx.query(wl.queries.get(qi), 8, &mut ops_fixed);
        }
        assert!(hits >= 45, "hits={hits}/60");
        assert!(
            ops_adaptive.scan_ops < ops_fixed.scan_ops,
            "adaptive {} !< full-poll {}",
            ops_adaptive.scan_ops,
            ops_fixed.scan_ops
        );
    }

    #[test]
    fn insert_then_query_finds_new_vector() {
        let (mut idx, _) = dense_index(9, 128, 4);
        let mut rng = Rng::new(99);
        let v: Vec<f32> =
            (0..64).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let id = idx.insert(&v).unwrap();
        assert_eq!(id, 128);
        assert_eq!(idx.len(), 129);
        idx.partition().validate().unwrap();
        let mut ops = OpsCounter::new();
        // full poll: the inserted vector must be its own NN
        let r = idx.query(&v, 4, &mut ops);
        assert_eq!(r.id(), id);
        assert_eq!(r.distance(), 0.0);
    }

    #[test]
    fn insert_keeps_classes_balanced_for_random_alloc() {
        let (mut idx, _) = dense_index(10, 120, 4);
        let mut rng = Rng::new(100);
        for _ in 0..40 {
            let v: Vec<f32> =
                (0..64).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
            idx.insert(&v).unwrap();
        }
        let sizes = idx.partition().sizes();
        let (min, max) = (
            *sizes.iter().min().unwrap(),
            *sizes.iter().max().unwrap(),
        );
        assert!(max - min <= 1, "sizes={sizes:?}");
    }

    #[test]
    fn insert_rejects_wrong_dim() {
        let (mut idx, _) = dense_index(11, 64, 4);
        assert!(idx.insert(&[1.0; 63]).is_err());
    }

    #[test]
    fn insert_updates_bank_scores_consistently() {
        let (mut idx, _) = dense_index(12, 64, 4);
        let mut rng = Rng::new(101);
        let v: Vec<f32> =
            (0..64).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let mut ops = OpsCounter::new();
        let before = idx.score_classes(&v, &mut ops);
        let id = idx.insert(&v).unwrap();
        let class = idx.partition().class_of(id as usize) as usize;
        let after = idx.score_classes(&v, &mut ops);
        // the chosen class gains exactly <v,v>^2 = (64)^2
        let gain = after[class] - before[class];
        assert!((gain - 4096.0).abs() < 1.0, "gain={gain}");
        for i in 0..4 {
            if i != class {
                assert!((after[i] - before[i]).abs() < 1e-2);
            }
        }
    }

    #[test]
    fn finish_batch_matches_finish_query_dense() {
        let (idx, wl) = dense_index(30, 256, 8);
        let b = 6;
        let queries: Vec<&[f32]> = (0..b).map(|i| wl.queries.get(i)).collect();
        let ps: Vec<usize> = vec![1, 2, 3, 8, 8, 5];
        // mixed k per query: 1 (legacy), mid-range, ≥ class size, > n
        let ks: Vec<usize> = vec![1, 4, 1, 33, 300, 7];
        let mut flat_scores = Vec::new();
        let mut seq_results = Vec::new();
        let mut seq_ops = Vec::new();
        for (bi, x) in queries.iter().enumerate() {
            let mut throwaway = OpsCounter::new();
            let scores = idx.score_classes(x, &mut throwaway);
            let mut o = OpsCounter::new();
            seq_results.push(idx.finish_query(x, &scores, ps[bi], ks[bi], &mut o));
            seq_ops.push(o);
            flat_scores.extend_from_slice(&scores);
        }
        let mut batch_ops = vec![OpsCounter::new(); b];
        let batch_results =
            idx.finish_batch(&queries, &flat_scores, &ps, &ks, &mut batch_ops);
        assert_eq!(batch_results, seq_results);
        assert_eq!(batch_ops, seq_ops);
    }

    #[test]
    fn finish_batch_handles_empty_classes_and_empty_polls() {
        // classes 0 and 1 are EMPTY; the probe scores every class 0, so
        // top-2 selection polls exactly the two empty classes
        let idx = two_empty_classes_fixture();
        let probe: Vec<f32> = vec![0., 0., 1.];
        let mut ops = OpsCounter::new();
        let scores = idx.score_classes(&probe, &mut ops);
        assert!(scores.iter().all(|&s| s == 0.0), "scores={scores:?}");

        let queries: Vec<&[f32]> = vec![&probe, &probe];
        let mut flat_scores = scores.clone();
        flat_scores.extend_from_slice(&scores);
        // query 0 polls the two empty classes (ties -> smallest index);
        // query 1 polls everything (p = q edge)
        let ps = vec![2usize, 4];
        let ks = vec![3usize, 2];
        let mut batch_ops = vec![OpsCounter::new(); 2];
        let results = idx.finish_batch(&queries, &flat_scores, &ps, &ks, &mut batch_ops);
        assert_eq!(results[0].polled, vec![0, 1]);
        assert_eq!(results[0].candidates, 0);
        assert!(results[0].neighbors.is_empty(), "no candidates -> empty");
        assert_eq!(results[0].id(), u32::MAX);
        assert!(results[0].distance().is_infinite());
        assert_eq!(results[1].candidates, 4);
        assert_eq!(results[1].neighbors.len(), 2);
        assert_eq!(results[1].polled.len(), 4);
        // bitwise identical to the sequential path on the same scores
        for bi in 0..2 {
            let mut o = OpsCounter::new();
            let seq = idx.finish_query(&probe, &scores, ps[bi], ks[bi], &mut o);
            assert_eq!(results[bi], seq);
            assert_eq!(batch_ops[bi], o);
        }
    }

    fn quant_pair(
        seed: u64,
        n: usize,
        q: usize,
        precision: crate::quant::ScanPrecision,
    ) -> (AmIndex, AmIndex, crate::data::Workload) {
        // identical build rngs -> identical partitions, so the scan
        // precision is the only difference between the two indices
        let mut rng = Rng::new(seed);
        let wl = synthetic::dense_workload(64, n, 30, QueryModel::Exact, &mut rng);
        let exact = AmIndex::build(
            wl.base.clone(),
            IndexParams { n_classes: q, ..Default::default() },
            &mut Rng::new(seed ^ 0xF00D),
        )
        .unwrap();
        let quantized = AmIndex::build(
            wl.base.clone(),
            IndexParams { n_classes: q, precision, ..Default::default() },
            &mut Rng::new(seed ^ 0xF00D),
        )
        .unwrap();
        (exact, quantized, wl)
    }

    #[test]
    fn quant_full_rerank_matches_exact_bitwise() {
        use crate::quant::ScanPrecision;
        for precision in [
            ScanPrecision::Sq8 { rerank: 0 },
            ScanPrecision::Pq { m: 8, bits: 4, rerank: 0 },
        ] {
            let (exact, quantized, wl) = quant_pair(40, 256, 8, precision);
            assert!(quantized.quant().is_some());
            let mut ops_e = OpsCounter::new();
            let mut ops_q = OpsCounter::new();
            for qi in 0..wl.queries.len() {
                let x = wl.queries.get(qi);
                for (p, k) in [(1usize, 1usize), (3, 5), (8, 300)] {
                    let a = exact.query_k(x, p, k, &mut ops_e);
                    let b = quantized.query_k(x, p, k, &mut ops_q);
                    assert_eq!(a.polled, b.polled, "{precision} q{qi} p{p} k{k}");
                    assert_eq!(a.candidates, b.candidates);
                    assert_eq!(
                        a.neighbors.len(),
                        b.neighbors.len(),
                        "{precision} q{qi} p{p} k{k}"
                    );
                    for (na, nb) in a.neighbors.iter().zip(&b.neighbors) {
                        assert_eq!(na.id, nb.id, "{precision} q{qi} p{p} k{k}");
                        assert_eq!(
                            na.distance.to_bits(),
                            nb.distance.to_bits(),
                            "{precision} q{qi} p{p} k{k}"
                        );
                    }
                }
            }
            // the exact path spent scan_ops; the quantized path split
            // its spend into compressed + rerank and spent no scan_ops
            assert!(ops_e.scan_ops > 0);
            assert_eq!(ops_e.compressed_ops, 0);
            assert_eq!(ops_q.scan_ops, 0);
            assert!(ops_q.compressed_ops > 0);
            assert!(ops_q.rerank_ops > 0);
        }
    }

    #[test]
    fn quant_finish_batch_matches_finish_query() {
        use crate::quant::ScanPrecision;
        let (_, idx, wl) =
            quant_pair(41, 256, 8, ScanPrecision::Sq8 { rerank: 7 });
        let b = 6;
        let queries: Vec<&[f32]> = (0..b).map(|i| wl.queries.get(i)).collect();
        let ps: Vec<usize> = vec![1, 2, 3, 8, 8, 5];
        let ks: Vec<usize> = vec![1, 4, 1, 33, 300, 7];
        let mut flat_scores = Vec::new();
        let mut seq_results = Vec::new();
        let mut seq_ops = Vec::new();
        for (bi, x) in queries.iter().enumerate() {
            let mut throwaway = OpsCounter::new();
            let scores = idx.score_classes(x, &mut throwaway);
            let mut o = OpsCounter::new();
            seq_results.push(idx.finish_query(x, &scores, ps[bi], ks[bi], &mut o));
            seq_ops.push(o);
            flat_scores.extend_from_slice(&scores);
        }
        let mut batch_ops = vec![OpsCounter::new(); b];
        let batch_results =
            idx.finish_batch(&queries, &flat_scores, &ps, &ks, &mut batch_ops);
        assert_eq!(batch_results, seq_results);
        assert_eq!(batch_ops, seq_ops);
    }

    #[test]
    fn quant_small_rerank_still_finds_stored_copy_at_full_poll() {
        use crate::quant::ScanPrecision;
        // rerank = 1 is the harshest setting: the exact stage only sees
        // the single best compressed candidate.  Queries are exact
        // copies of stored vectors, whose compressed distance is the
        // (near-)minimum, so even r = 1 finds them at full poll.
        let (_, idx, wl) = quant_pair(42, 128, 4, ScanPrecision::Sq8 { rerank: 1 });
        let mut ops = OpsCounter::new();
        let mut hits = 0;
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let r = idx.query(wl.queries.get(qi), 4, &mut ops);
            assert_eq!(r.neighbors.len(), 1, "rerank=1 returns one candidate");
            if r.id() == gt {
                hits += 1;
            }
        }
        assert!(hits >= 28, "hits={hits}/30");
    }

    #[test]
    fn quant_insert_then_query_finds_new_vector() {
        use crate::quant::ScanPrecision;
        let (_, mut idx, _) = quant_pair(43, 128, 4, ScanPrecision::Sq8 { rerank: 0 });
        let mut rng = Rng::new(99);
        let v: Vec<f32> =
            (0..64).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let id = idx.insert(&v).unwrap();
        assert_eq!(idx.quant().unwrap().len(), idx.len());
        let mut ops = OpsCounter::new();
        let r = idx.query(&v, 4, &mut ops);
        assert_eq!(r.id(), id);
        assert_eq!(r.distance(), 0.0);
    }

    #[test]
    fn quant_footprint_reports_compression() {
        use crate::quant::ScanPrecision;
        let (exact, sq8, _) = quant_pair(44, 256, 8, ScanPrecision::Sq8 { rerank: 8 });
        let fe = exact.footprint();
        assert_eq!(fe.bytes, 256 * 64 * 4);
        assert_eq!(fe.compressed_bytes, fe.bytes);
        assert_eq!(exact.quant_mode(), "exact");
        let fq = sq8.footprint();
        assert_eq!(fq.bytes, fe.bytes);
        assert!(
            fq.ratio() <= 0.35,
            "sq8 must compress below 0.35x, got {}",
            fq.ratio()
        );
        assert_eq!(sq8.quant_mode(), "sq8");
        let (_, pq, _) = quant_pair(
            44,
            256,
            8,
            ScanPrecision::Pq { m: 8, bits: 8, rerank: 8 },
        );
        assert!(
            pq.footprint().compressed_bytes < fq.compressed_bytes,
            "pq ({}) must be smaller than sq8 ({})",
            pq.footprint().compressed_bytes,
            fq.compressed_bytes
        );
    }

    #[test]
    fn set_scan_rerank_updates_params_and_codes() {
        use crate::quant::ScanPrecision;
        let (_, mut idx, _) = quant_pair(45, 128, 4, ScanPrecision::Sq8 { rerank: 4 });
        idx.set_scan_rerank(16);
        assert_eq!(idx.params().precision, ScanPrecision::Sq8 { rerank: 16 });
        assert_eq!(idx.quant().unwrap().rerank(), 16);
    }

    #[test]
    fn exhaustive_exact_matches_full_poll_query() {
        let (idx, wl) = dense_index(50, 128, 4);
        let mut ops = OpsCounter::new();
        for qi in 0..10 {
            let x = wl.queries.get(qi);
            // p = q scans every vector, so the poll result IS the
            // exhaustive top-k
            let r = idx.query_k(x, 4, 5, &mut ops);
            assert_eq!(idx.exhaustive_exact(x, 5), r.neighbors, "query {qi}");
        }
    }

    #[test]
    fn resident_store_stats_report_full_residency() {
        let (idx, _) = dense_index(51, 128, 4);
        assert!(!idx.is_paged());
        assert!(idx.store_error().is_none());
        assert_eq!(idx.store().kind(), "resident");
        let s = idx.store_stats();
        assert_eq!(s.kind, "resident");
        assert_eq!(s.bytes_resident, 128 * 64 * 4);
        assert_eq!(s.bytes_disk, 0);
        assert_eq!(s.bytes_read, 0);
        assert_eq!(s.cache_hits + s.cache_misses, 0);
    }

    #[test]
    fn greedy_allocation_builds() {
        let mut rng = Rng::new(8);
        let wl = synthetic::dense_workload(32, 120, 5, QueryModel::Exact, &mut rng);
        let params = IndexParams {
            n_classes: 4,
            allocation: Allocation::Greedy,
            greedy_cap_factor: Some(1.5),
            ..Default::default()
        };
        let idx = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        idx.partition().validate().unwrap();
        let cap = ((120.0 / 4.0) * 1.5_f64).ceil() as usize;
        assert!(idx.partition().sizes().iter().all(|&s| s <= cap));
    }
}
