//! Index persistence: a versioned little-endian binary format so a built
//! index can be served without rebuilding (allocation + memory build is
//! the expensive part for large corpora).
//!
//! The checksummed reader/writer machinery here is also the substrate
//! of the **shard manifest format (v3)** — the cluster plan file
//! (`cluster.amplan`, see [`crate::cluster::plan`]) that carries the
//! routing table (per-shard summed super-memories), the per-shard
//! id/class maps, and the shard artifact file names.  Shard indices
//! themselves are ordinary index files written by [`save`].
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic    8B   "AMSEARCH"
//! version  u32  (currently 5; v3 is the shard-manifest format)
//! dim      u32
//! n        u64  number of vectors
//! q        u32  number of classes
//! top_p    u32
//! top_k    u32  (v2+; default neighbors returned per query)
//! rule     u8   0 = sum, 1 = max
//! alloc    u8   0 = random, 1 = greedy, 2 = round_robin
//! metric   u8   0 = sq_l2, 1 = neg_dot, 2 = hamming
//! cap      f64  greedy cap factor (NaN = none)
//! quant    u8   (v4+) 0 = exact, 1 = sq8, 2 = pq
//!   sq8:   rerank u32
//!   pq:    m u32, bits u32, rerank u32, n_centroids u32
//! flags    u8   (v5+) bit 0 = binary sparse scoring
//! data_len u64  (v5+) byte length of the `.amdat` sibling
//! table_fnv u64 (v5+) extent-table checksum of the `.amdat` sibling
//! assignments  n * u32
//! bank         q * dim * dim * f32
//! counts       q * u64
//! data         n * dim * f32  (v4 and earlier only)
//! quant payload (v4+, per the quant byte):
//!   sq8:   min dim * f32, step dim * f32, codes n * dim * u8
//!   pq:    codebooks m * n_centroids * (dim/m) * f32, codes n * m * u8
//! checksum u64  FNV-1a of everything before it
//! ```
//!
//! The quant section makes a compressed index a first-class artifact:
//! codebooks and codes are persisted (not retrained on load), so a
//! served index is byte-for-byte the one that was built.  v1/v2 files
//! keep loading unchanged (no quant section, `ScanPrecision::Exact`).
//!
//! **v5 splits the artifact in two.**  The `.amidx` keeps only the hot
//! state (AM super-memories, assignments, quantizer tables + codes);
//! the exact f32 member matrices move to a class-extent data file next
//! to it (`<stem>.amdat`, [`crate::store`], spec in
//! `docs/STORE_FORMAT.md`).  The header's `data_len`/`table_fnv` bind
//! the pair, so a stale or swapped data file is rejected at load.
//! [`load`] rehydrates a fully memory-resident index from both files;
//! [`load_paged`] keeps the data file on disk and serves exact rows
//! through the paged store.  v4 files still load resident-only; loading
//! one paged fails with a migration hint (load + [`save`] rewrites it
//! as v5).

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use crate::data::dataset::Dataset;
use crate::error::{Error, Result};
use crate::memory::StorageRule;
use crate::partition::Allocation;
use crate::quant::{PqQuantizer, QuantIndex, Quantizer, ScanPrecision, Sq8Quantizer};
use crate::search::Metric;
use crate::store::{write_data_file, DataFile, Fnv, PagedStore};

use super::am_index::AmIndex;
use super::params::IndexParams;

const MAGIC: &[u8; 8] = b"AMSEARCH";
const VERSION: u32 = 5;

/// Version stamp of the shard manifest format (a member of the shared
/// index-format family: index v1 = 1-NN, v2 = per-request k, v3 = the
/// cluster plan / routing table, v4 = quantized index artifacts, v5 =
/// split hot state / class-extent data file).
pub(crate) const SHARD_MANIFEST_VERSION: u32 = 3;

/// The class-extent data file that rides next to a v5 `.amidx`:
/// `<stem>.amdat` in the same directory.
pub fn data_path(path: &Path) -> PathBuf {
    path.with_extension("amdat")
}

pub(crate) struct CountingWriter<W: Write> {
    inner: W,
    hash: Fnv,
}

impl<W: Write> CountingWriter<W> {
    pub(crate) fn new(inner: W) -> Self {
        CountingWriter { inner, hash: Fnv::new() }
    }

    pub(crate) fn put(&mut self, data: &[u8]) -> Result<()> {
        self.hash.update(data);
        self.inner.write_all(data)?;
        Ok(())
    }

    /// Append the checksum of everything written so far and flush.
    pub(crate) fn finish(mut self) -> Result<()> {
        let checksum = self.hash.value();
        self.inner.write_all(&checksum.to_le_bytes())?;
        self.inner.flush()?;
        Ok(())
    }
}

/// Save an index to `path` (v5: `.amidx` hot state plus the
/// class-extent `.amdat` data file next to it).
///
/// Only memory-resident indices can be saved: a paged index has no
/// in-RAM member matrices to write — its artifacts on disk already
/// *are* the saved form.
pub fn save(index: &AmIndex, path: &Path) -> Result<()> {
    if index.is_paged() {
        return Err(Error::Config(
            "cannot re-save a paged index: its .amidx/.amdat artifacts are \
             already the persisted form (copy the files instead)"
                .into(),
        ));
    }
    // the data file first: the .amidx header records its length and
    // table checksum to bind the pair
    let (data_len, table_fnv) =
        write_data_file(&data_path(path), index.data(), index.partition())?;
    let file = std::fs::File::create(path)?;
    let mut w = CountingWriter::new(BufWriter::new(file));
    let p = index.params();

    w.put(MAGIC)?;
    w.put(&VERSION.to_le_bytes())?;
    w.put(&(index.dim() as u32).to_le_bytes())?;
    w.put(&(index.len() as u64).to_le_bytes())?;
    w.put(&(p.n_classes as u32).to_le_bytes())?;
    w.put(&(p.top_p as u32).to_le_bytes())?;
    w.put(&(p.top_k as u32).to_le_bytes())?;
    w.put(&[match p.rule {
        StorageRule::Sum => 0u8,
        StorageRule::Max => 1,
    }])?;
    w.put(&[match p.allocation {
        Allocation::Random => 0u8,
        Allocation::Greedy => 1,
        Allocation::RoundRobin => 2,
    }])?;
    w.put(&[match p.metric {
        Metric::SqL2 => 0u8,
        Metric::NegDot => 1,
        Metric::Hamming => 2,
    }])?;
    w.put(&p.greedy_cap_factor.unwrap_or(f64::NAN).to_le_bytes())?;
    // v4 quant header: the precision the artifact's payload encodes
    match index.quant() {
        None => w.put(&[0u8])?,
        Some(q) => match q.quantizer() {
            Quantizer::Sq8(_) => {
                w.put(&[1u8])?;
                w.put(&(q.rerank() as u32).to_le_bytes())?;
            }
            Quantizer::Pq(pq) => {
                w.put(&[2u8])?;
                w.put(&(pq.m() as u32).to_le_bytes())?;
                w.put(&(pq.bits() as u32).to_le_bytes())?;
                w.put(&(q.rerank() as u32).to_le_bytes())?;
                w.put(&(pq.n_centroids() as u32).to_le_bytes())?;
            }
        },
    }
    // v5 trailer of the header: sparse-scoring flag (not derivable from
    // an on-disk dataset) and the data-file binding
    w.put(&[if index.uses_sparse_scoring() { 1u8 } else { 0 }])?;
    w.put(&data_len.to_le_bytes())?;
    w.put(&table_fnv.to_le_bytes())?;

    for v in 0..index.len() {
        w.put(&index.partition().class_of(v).to_le_bytes())?;
    }
    for &x in index.bank().stacked() {
        w.put(&x.to_le_bytes())?;
    }
    for i in 0..p.n_classes {
        w.put(&(index.bank().count(i) as u64).to_le_bytes())?;
    }
    // v5 keeps no inline data: exact f32 rows live in the .amdat
    // v4 quant payload: codebooks/tables then the code rows
    if let Some(quant) = index.quant() {
        match quant.quantizer() {
            Quantizer::Sq8(sq) => {
                for &x in sq.min() {
                    w.put(&x.to_le_bytes())?;
                }
                for &x in sq.step() {
                    w.put(&x.to_le_bytes())?;
                }
            }
            Quantizer::Pq(pq) => {
                for &x in pq.codebooks() {
                    w.put(&x.to_le_bytes())?;
                }
            }
        }
        w.put(quant.codes())?;
    }
    w.finish()
}

pub(crate) struct CountingReader<R: Read> {
    inner: R,
    hash: Fnv,
}

impl<R: Read> CountingReader<R> {
    pub(crate) fn new(inner: R) -> Self {
        CountingReader { inner, hash: Fnv::new() }
    }
    pub(crate) fn take(&mut self, buf: &mut [u8]) -> Result<()> {
        self.inner.read_exact(buf)?;
        self.hash.update(buf);
        Ok(())
    }
    pub(crate) fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.take(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    pub(crate) fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.take(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    pub(crate) fn u8(&mut self) -> Result<u8> {
        let mut b = [0u8; 1];
        self.take(&mut b)?;
        Ok(b[0])
    }
    pub(crate) fn f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.take(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }
    pub(crate) fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let mut bytes = vec![0u8; n * 4];
        self.take(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    /// Read the trailing checksum and compare with everything consumed.
    pub(crate) fn verify_checksum(mut self) -> Result<()> {
        let computed = self.hash.value();
        let mut tail = [0u8; 8];
        self.inner.read_exact(&mut tail)?;
        let stored = u64::from_le_bytes(tail);
        if computed != stored {
            return Err(Error::Data(format!(
                "file corrupt: checksum {computed:#x} != stored {stored:#x}"
            )));
        }
        Ok(())
    }
}

/// Everything a `.amidx` holds, parsed and checksum-verified but not
/// yet bound to a vector store.
struct Artifact {
    version: u32,
    dim: usize,
    q: usize,
    n: usize,
    params: IndexParams,
    /// v5 flags bit 0: the index uses binary sparse scoring.
    sparse: bool,
    /// v5 binding: byte length of the `.amdat` sibling.
    data_len: u64,
    /// v5 binding: extent-table checksum of the `.amdat` sibling.
    table_fnv: u64,
    assignments: Vec<u32>,
    stacked: Vec<f32>,
    counts: Vec<usize>,
    /// Inline exact rows (v4 and earlier; empty for v5).
    flat: Vec<f32>,
    quant: Option<QuantIndex>,
}

fn read_artifact(path: &Path) -> Result<Artifact> {
    let file = std::fs::File::open(path)
        .map_err(|e| Error::Data(format!("cannot open {}: {e}", path.display())))?;
    let mut r = CountingReader::new(BufReader::new(file));

    let mut magic = [0u8; 8];
    r.take(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Data("not an amsearch index file".into()));
    }
    let version = r.u32()?;
    // v3 of the format family is the shard manifest (different magic,
    // never a valid index version); everything else up to VERSION loads
    if version == 0 || version == SHARD_MANIFEST_VERSION || version > VERSION {
        return Err(Error::Data(format!("unsupported index version {version}")));
    }
    let dim = r.u32()? as usize;
    let n = r.u64()? as usize;
    let q = r.u32()? as usize;
    let top_p = r.u32()? as usize;
    // v1 files predate per-request k and default to 1-NN
    let top_k = if version >= 2 { r.u32()? as usize } else { 1 };
    let rule = match r.u8()? {
        0 => StorageRule::Sum,
        1 => StorageRule::Max,
        x => return Err(Error::Data(format!("bad rule byte {x}"))),
    };
    let allocation = match r.u8()? {
        0 => Allocation::Random,
        1 => Allocation::Greedy,
        2 => Allocation::RoundRobin,
        x => return Err(Error::Data(format!("bad allocation byte {x}"))),
    };
    let metric = match r.u8()? {
        0 => Metric::SqL2,
        1 => Metric::NegDot,
        2 => Metric::Hamming,
        x => return Err(Error::Data(format!("bad metric byte {x}"))),
    };
    let cap = r.f64()?;
    // v4 quant header (absent before v4: those files are exact)
    let quant_header = if version >= 4 {
        match r.u8()? {
            0 => QuantHeader::Exact,
            1 => QuantHeader::Sq8 { rerank: r.u32()? as usize },
            2 => {
                let m = r.u32()? as usize;
                let bits = r.u32()? as usize;
                let rerank = r.u32()? as usize;
                let n_centroids = r.u32()? as usize;
                QuantHeader::Pq { m, bits, rerank, n_centroids }
            }
            x => return Err(Error::Data(format!("bad quant byte {x}"))),
        }
    } else {
        QuantHeader::Exact
    };
    // v5 header trailer: flags byte plus the data-file binding
    let (flags, data_len, table_fnv) = if version >= 5 {
        let flags = r.u8()?;
        if flags & !1 != 0 {
            return Err(Error::Data(format!("bad flags byte {flags:#x}")));
        }
        (flags, r.u64()?, r.u64()?)
    } else {
        (0u8, 0u64, 0u64)
    };
    let precision = match quant_header {
        QuantHeader::Exact => ScanPrecision::Exact,
        QuantHeader::Sq8 { rerank } => ScanPrecision::Sq8 { rerank },
        QuantHeader::Pq { m, bits, rerank, .. } => ScanPrecision::Pq { m, bits, rerank },
    };
    precision.validate_for_dim(dim)?;
    let params = IndexParams {
        n_classes: q,
        top_p,
        top_k,
        rule,
        allocation,
        metric,
        greedy_cap_factor: if cap.is_nan() { None } else { Some(cap) },
        precision,
    };

    let mut assignments = Vec::with_capacity(n);
    for _ in 0..n {
        assignments.push(r.u32()?);
    }
    let stacked = r.f32_vec(q * dim * dim)?;
    let mut counts = Vec::with_capacity(q);
    for _ in 0..q {
        counts.push(r.u64()? as usize);
    }
    // v5 files carry no inline data; exact rows live in the .amdat
    let flat = if version >= 5 { Vec::new() } else { r.f32_vec(n * dim)? };
    // v4 quant payload: quantizer tables, then one code row per vector
    let quant = match quant_header {
        QuantHeader::Exact => None,
        QuantHeader::Sq8 { rerank } => {
            let min = r.f32_vec(dim)?;
            let step = r.f32_vec(dim)?;
            let mut codes = vec![0u8; n * dim];
            r.take(&mut codes)?;
            Some(QuantIndex::from_parts(
                Quantizer::Sq8(Sq8Quantizer::from_parts(min, step)),
                codes,
                rerank,
            )?)
        }
        QuantHeader::Pq { m, bits, rerank, n_centroids } => {
            if n_centroids == 0 || n_centroids > 256 || m == 0 || m > dim {
                return Err(Error::Data(format!(
                    "implausible pq header: m={m} n_centroids={n_centroids}"
                )));
            }
            let codebooks = r.f32_vec(m * n_centroids * (dim / m))?;
            let mut codes = vec![0u8; n * m];
            r.take(&mut codes)?;
            Some(QuantIndex::from_parts(
                Quantizer::Pq(PqQuantizer::from_parts(dim, m, bits, n_centroids, codebooks)?),
                codes,
                rerank,
            )?)
        }
    };
    r.verify_checksum()?;

    Ok(Artifact {
        version,
        dim,
        q,
        n,
        params,
        sparse: flags & 1 != 0,
        data_len,
        table_fnv,
        assignments,
        stacked,
        counts,
        flat,
        quant,
    })
}

/// Load a fully memory-resident index from `path`.  For v5 artifacts
/// this rehydrates the member matrices from the `.amdat` sibling
/// (verifying every extent checksum once).
pub fn load(path: &Path) -> Result<AmIndex> {
    let a = read_artifact(path)?;
    let flat = if a.version >= 5 {
        let mut df = DataFile::open(&data_path(path))?;
        df.check_binding(a.dim, a.q, a.n, a.data_len, a.table_fnv)?;
        gather_flat(&mut df, &a.assignments, a.dim, a.q, a.n)?
    } else {
        a.flat
    };
    let data = Dataset::from_flat(a.dim, flat)?;
    AmIndex::from_parts_with_quant(a.params, a.assignments, a.stacked, a.counts, data, a.quant)
}

/// Load an index from `path` with the exact member matrices left on
/// disk, served through a paged store with an extent-cache budget of
/// `cache_bytes` (see [`crate::store`]).
pub fn load_paged(path: &Path, cache_bytes: u64) -> Result<AmIndex> {
    let a = read_artifact(path)?;
    if a.version < 5 {
        return Err(Error::Config(format!(
            "index version {} predates the paged data file; load it resident \
             and re-save to migrate it to v5",
            a.version
        )));
    }
    let df = DataFile::open(&data_path(path))?;
    df.check_binding(a.dim, a.q, a.n, a.data_len, a.table_fnv)?;
    let store = PagedStore::from_data_file(df, &a.assignments, cache_bytes)?;
    AmIndex::from_parts_paged(
        a.params,
        a.assignments,
        a.stacked,
        a.counts,
        a.dim,
        a.sparse,
        a.quant,
        store,
    )
}

/// Rehydrate the flat `[n × dim]` vid-order dataset from per-class
/// extents (the extents hold rows in members-list order).
fn gather_flat(
    df: &mut DataFile,
    assignments: &[u32],
    dim: usize,
    q: usize,
    n: usize,
) -> Result<Vec<f32>> {
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); q];
    for (vid, &c) in assignments.iter().enumerate() {
        let Some(m) = members.get_mut(c as usize) else {
            return Err(Error::Data(format!("assignment to class {c} >= q = {q}")));
        };
        m.push(vid);
    }
    let mut flat = vec![0f32; n * dim];
    for (ci, m) in members.iter().enumerate() {
        let rows = df.read_class(ci)?;
        if rows.len() != m.len() * dim {
            return Err(Error::Data(format!(
                "class {ci}: extent holds {} floats, members need {}",
                rows.len(),
                m.len() * dim
            )));
        }
        for (i, &vid) in m.iter().enumerate() {
            flat[vid * dim..(vid + 1) * dim]
                .copy_from_slice(&rows[i * dim..(i + 1) * dim]);
        }
    }
    Ok(flat)
}

/// Parsed v4 quant header (precision + the PQ codebook size the payload
/// was written with).
#[derive(Debug, Clone, Copy)]
enum QuantHeader {
    Exact,
    Sq8 { rerank: usize },
    Pq { m: usize, bits: usize, rerank: usize, n_centroids: usize },
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::data::synthetic::{self, QueryModel};
    use crate::metrics::OpsCounter;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("amsearch_persist_{}_{}", std::process::id(), name))
    }

    /// Remove a test artifact and its `.amdat` sibling.
    fn cleanup(path: &Path) {
        std::fs::remove_file(path).ok();
        std::fs::remove_file(data_path(path)).ok();
    }

    fn build(seed: u64) -> (AmIndex, crate::data::Workload) {
        let mut rng = Rng::new(seed);
        let wl = synthetic::dense_workload(16, 120, 20, QueryModel::Exact, &mut rng);
        let params =
            IndexParams { n_classes: 6, top_p: 2, top_k: 3, ..Default::default() };
        (AmIndex::build(wl.base.clone(), params, &mut rng).unwrap(), wl)
    }

    #[test]
    fn roundtrip_preserves_queries() {
        let (index, wl) = build(1);
        let path = tmp("rt.amidx");
        save(&index, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), index.len());
        assert_eq!(loaded.dim(), index.dim());
        assert_eq!(loaded.params().n_classes, 6);
        assert_eq!(loaded.params().top_p, 2);
        assert_eq!(loaded.params().top_k, 3);
        let mut ops = OpsCounter::new();
        for qi in 0..wl.queries.len() {
            let x = wl.queries.get(qi);
            let a = index.query(x, 2, &mut ops);
            let b = loaded.query(x, 2, &mut ops);
            assert_eq!(a, b, "query {qi}");
        }
        cleanup(&path);
    }

    fn build_quant(seed: u64, precision: ScanPrecision) -> (AmIndex, crate::data::Workload) {
        let mut rng = Rng::new(seed);
        let wl = synthetic::dense_workload(16, 120, 20, QueryModel::Exact, &mut rng);
        let params = IndexParams {
            n_classes: 6,
            top_p: 2,
            top_k: 3,
            precision,
            ..Default::default()
        };
        (AmIndex::build(wl.base.clone(), params, &mut rng).unwrap(), wl)
    }

    #[test]
    fn quantized_roundtrip_preserves_queries_and_codes() {
        for precision in [
            ScanPrecision::Sq8 { rerank: 5 },
            ScanPrecision::Pq { m: 4, bits: 4, rerank: 0 },
        ] {
            let (index, wl) = build_quant(10, precision);
            let path = tmp(&format!("rt_{}.amidx", precision.mode()));
            save(&index, &path).unwrap();
            let loaded = load(&path).unwrap();
            assert_eq!(loaded.params().precision, precision);
            // codes and quantizer survive byte-for-byte — no retraining
            assert_eq!(loaded.quant(), index.quant());
            assert_eq!(loaded.footprint(), index.footprint());
            let mut ops = OpsCounter::new();
            for qi in 0..wl.queries.len() {
                let x = wl.queries.get(qi);
                let a = index.query_k(x, 3, 4, &mut ops);
                let b = loaded.query_k(x, 3, 4, &mut ops);
                assert_eq!(a, b, "{precision} query {qi}");
            }
            cleanup(&path);
        }
    }

    #[test]
    fn quantized_artifact_is_smaller_than_exact_in_the_data_section() {
        // the artifact keeps the f32 vectors for the exact rerank, so
        // the *file* grows by the code bytes — but the scan-resident
        // representation it reports is what matters for serving memory
        let (index, _) = build_quant(11, ScanPrecision::Sq8 { rerank: 4 });
        let fp = index.footprint();
        assert!(
            (fp.compressed_bytes as f64) <= 0.35 * fp.bytes as f64,
            "sq8 compressed {} vs f32 {}",
            fp.compressed_bytes,
            fp.bytes
        );
    }

    /// Write `index` in the historical v2 layout (pre-quant): the
    /// backward-compat fixture for `v2_artifacts_still_load`.
    fn save_v2(index: &AmIndex, path: &Path) -> Result<()> {
        let file = std::fs::File::create(path)?;
        let mut w = CountingWriter::new(BufWriter::new(file));
        let p = index.params();
        w.put(MAGIC)?;
        w.put(&2u32.to_le_bytes())?;
        w.put(&(index.dim() as u32).to_le_bytes())?;
        w.put(&(index.len() as u64).to_le_bytes())?;
        w.put(&(p.n_classes as u32).to_le_bytes())?;
        w.put(&(p.top_p as u32).to_le_bytes())?;
        w.put(&(p.top_k as u32).to_le_bytes())?;
        w.put(&[0u8])?; // sum rule
        w.put(&[0u8])?; // random allocation
        w.put(&[0u8])?; // sq_l2
        w.put(&f64::NAN.to_le_bytes())?;
        for v in 0..index.len() {
            w.put(&index.partition().class_of(v).to_le_bytes())?;
        }
        for &x in index.bank().stacked() {
            w.put(&x.to_le_bytes())?;
        }
        for i in 0..p.n_classes {
            w.put(&(index.bank().count(i) as u64).to_le_bytes())?;
        }
        for &x in index.data().as_flat() {
            w.put(&x.to_le_bytes())?;
        }
        w.finish()
    }

    #[test]
    fn v2_artifacts_still_load() {
        let (index, wl) = build(7);
        let path = tmp("v2.amidx");
        save_v2(&index, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.params().precision, ScanPrecision::Exact);
        assert!(loaded.quant().is_none());
        let mut ops = OpsCounter::new();
        for qi in 0..wl.queries.len() {
            let x = wl.queries.get(qi);
            assert_eq!(index.query(x, 2, &mut ops), loaded.query(x, 2, &mut ops));
        }
        cleanup(&path);
    }

    /// Write `index` in the historical v4 layout (inline data, no
    /// data-file sibling): the migration fixture.
    fn save_v4(index: &AmIndex, path: &Path) -> Result<()> {
        let file = std::fs::File::create(path)?;
        let mut w = CountingWriter::new(BufWriter::new(file));
        let p = index.params();
        w.put(MAGIC)?;
        w.put(&4u32.to_le_bytes())?;
        w.put(&(index.dim() as u32).to_le_bytes())?;
        w.put(&(index.len() as u64).to_le_bytes())?;
        w.put(&(p.n_classes as u32).to_le_bytes())?;
        w.put(&(p.top_p as u32).to_le_bytes())?;
        w.put(&(p.top_k as u32).to_le_bytes())?;
        w.put(&[match p.rule {
            StorageRule::Sum => 0u8,
            StorageRule::Max => 1,
        }])?;
        w.put(&[match p.allocation {
            Allocation::Random => 0u8,
            Allocation::Greedy => 1,
            Allocation::RoundRobin => 2,
        }])?;
        w.put(&[match p.metric {
            Metric::SqL2 => 0u8,
            Metric::NegDot => 1,
            Metric::Hamming => 2,
        }])?;
        w.put(&p.greedy_cap_factor.unwrap_or(f64::NAN).to_le_bytes())?;
        match index.quant() {
            None => w.put(&[0u8])?,
            Some(q) => match q.quantizer() {
                Quantizer::Sq8(_) => {
                    w.put(&[1u8])?;
                    w.put(&(q.rerank() as u32).to_le_bytes())?;
                }
                Quantizer::Pq(pq) => {
                    w.put(&[2u8])?;
                    w.put(&(pq.m() as u32).to_le_bytes())?;
                    w.put(&(pq.bits() as u32).to_le_bytes())?;
                    w.put(&(q.rerank() as u32).to_le_bytes())?;
                    w.put(&(pq.n_centroids() as u32).to_le_bytes())?;
                }
            },
        }
        for v in 0..index.len() {
            w.put(&index.partition().class_of(v).to_le_bytes())?;
        }
        for &x in index.bank().stacked() {
            w.put(&x.to_le_bytes())?;
        }
        for i in 0..p.n_classes {
            w.put(&(index.bank().count(i) as u64).to_le_bytes())?;
        }
        for &x in index.data().as_flat() {
            w.put(&x.to_le_bytes())?;
        }
        if let Some(quant) = index.quant() {
            match quant.quantizer() {
                Quantizer::Sq8(sq) => {
                    for &x in sq.min() {
                        w.put(&x.to_le_bytes())?;
                    }
                    for &x in sq.step() {
                        w.put(&x.to_le_bytes())?;
                    }
                }
                Quantizer::Pq(pq) => {
                    for &x in pq.codebooks() {
                        w.put(&x.to_le_bytes())?;
                    }
                }
            }
            w.put(quant.codes())?;
        }
        w.finish()
    }

    /// The migration property: for seeded exact and quantized indices,
    /// a v4 artifact, its v5 re-save (the migration path), and the v5
    /// paged load all answer every query identically.
    #[test]
    fn v4_to_v5_migration_preserves_query_results() {
        for (seed, precision) in [
            (21, ScanPrecision::Exact),
            (22, ScanPrecision::Sq8 { rerank: 5 }),
            (23, ScanPrecision::Pq { m: 4, bits: 4, rerank: 0 }),
        ] {
            let (index, wl) = build_quant(seed, precision);
            let v4 = tmp(&format!("mig_v4_{}.amidx", precision.mode()));
            let v5 = tmp(&format!("mig_v5_{}.amidx", precision.mode()));
            save_v4(&index, &v4).unwrap();
            let from_v4 = load(&v4).unwrap();
            // migration: load the v4 resident, save → the v5 pair
            save(&from_v4, &v5).unwrap();
            let resident = load(&v5).unwrap();
            assert_eq!(resident.quant(), index.quant());
            // the hot-state file shed its inline data section
            let v4_len = std::fs::metadata(&v4).unwrap().len();
            let v5_len = std::fs::metadata(&v5).unwrap().len();
            assert!(v5_len < v4_len, "{precision}: v5 {v5_len} vs v4 {v4_len}");
            let mut loaded = vec![("v4", from_v4), ("v5", resident)];
            if cfg!(unix) {
                let paged = load_paged(&v5, 1 << 20).unwrap();
                assert!(paged.is_paged());
                loaded.push(("paged", paged));
            }
            let mut ops = OpsCounter::new();
            for qi in 0..wl.queries.len() {
                let x = wl.queries.get(qi);
                let want = index.query_k(x, 3, 4, &mut ops);
                for (name, ix) in &loaded {
                    assert_eq!(
                        want,
                        ix.query_k(x, 3, 4, &mut ops),
                        "{precision} {name} query {qi}"
                    );
                }
            }
            for (name, ix) in &loaded {
                assert!(ix.store_error().is_none(), "{name}");
            }
            cleanup(&v4);
            cleanup(&v5);
        }
    }

    #[test]
    fn load_paged_on_v4_says_how_to_migrate() {
        let (index, _) = build(12);
        let path = tmp("v4_paged.amidx");
        save_v4(&index, &path).unwrap();
        let err = load_paged(&path, 1 << 20).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        assert!(err.to_string().contains("re-save"), "{err}");
        cleanup(&path);
    }

    #[test]
    fn missing_or_stale_data_file_is_rejected() {
        let (index, _) = build(13);
        let path = tmp("bind.amidx");
        save(&index, &path).unwrap();
        // stale: overwrite the sibling with a different index's data
        let (other, _) = build(14);
        write_data_file(&data_path(&path), other.data(), other.partition()).unwrap();
        assert!(load(&path).is_err(), "stale data file must not load");
        // missing entirely
        std::fs::remove_file(data_path(&path)).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("amdat"), "{err}");
        cleanup(&path);
    }

    #[cfg(unix)]
    #[test]
    fn paged_indices_cannot_be_resaved() {
        let (index, _) = build(15);
        let path = tmp("resave.amidx");
        save(&index, &path).unwrap();
        let paged = load_paged(&path, 1 << 20).unwrap();
        let err = save(&paged, &tmp("resave2.amidx")).unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err}");
        cleanup(&path);
        cleanup(&tmp("resave2.amidx"));
    }

    #[test]
    fn version_3_is_reserved_for_shard_manifests() {
        let (index, _) = build(8);
        let path = tmp("v3.amidx");
        save(&index, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8..12].copy_from_slice(&3u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("unsupported index version 3"));
        cleanup(&path);
    }

    #[test]
    fn corruption_detected() {
        let (index, _) = build(2);
        let path = tmp("corrupt.amidx");
        save(&index, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("corrupt") || err.to_string().contains("bad"));
        cleanup(&path);
    }

    #[test]
    fn rejects_wrong_magic() {
        let path = tmp("magic.amidx");
        std::fs::write(&path, b"NOTANIDXFILE....").unwrap();
        assert!(load(&path).is_err());
        cleanup(&path);
    }

    #[test]
    fn truncated_file_is_error_not_panic() {
        let (index, _) = build(3);
        let path = tmp("trunc.amidx");
        save(&index, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 3]).unwrap();
        assert!(load(&path).is_err());
        cleanup(&path);
    }
}
