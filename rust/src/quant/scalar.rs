//! Scalar 8-bit quantization (SQ8): per-dimension affine codes.
//!
//! Training learns one `(min, step)` pair per dimension over the
//! database; a coordinate is stored as
//! `code = round((x - min) / step)` clamped to `0..=255` (one byte), and
//! decodes to `min + step · code`.  The asymmetric distance against an
//! f32 query folds the offset into a per-query residual computed once
//! (`r = x - min`), so the per-candidate kernel is
//! `Σ_j (r_j - step_j · code_j)²` — a fused loop over the integer codes
//! that shares the early-abandon accumulation of the f32 scan through
//! [`crate::search::DistanceKernel`].

use crate::data::dataset::Dataset;
use crate::search::DistanceKernel;

/// Trained per-dimension affine 8-bit quantizer.
#[derive(Debug, Clone, PartialEq)]
pub struct Sq8Quantizer {
    /// Per-dimension offset (the observed minimum).
    min: Vec<f32>,
    /// Per-dimension step `(max - min) / 255`, forced positive so a
    /// constant dimension encodes to code 0 and decodes exactly.
    step: Vec<f32>,
}

impl Sq8Quantizer {
    /// Learn per-dimension ranges over `data` (must be non-empty; the
    /// index guarantees `n ≥ 1`).
    pub fn train(data: &Dataset) -> Sq8Quantizer {
        let d = data.dim();
        if data.is_empty() {
            // degenerate but total: identity-ish ranges, every code 0
            return Sq8Quantizer { min: vec![0.0; d], step: vec![1.0; d] };
        }
        let mut min = vec![f32::INFINITY; d];
        let mut max = vec![f32::NEG_INFINITY; d];
        for v in data.iter() {
            for j in 0..d {
                if v[j] < min[j] {
                    min[j] = v[j];
                }
                if v[j] > max[j] {
                    max[j] = v[j];
                }
            }
        }
        let step = (0..d)
            .map(|j| {
                let s = (max[j] - min[j]) / 255.0;
                if s > 0.0 {
                    s
                } else {
                    1.0 // constant dimension: every code is 0
                }
            })
            .collect();
        Sq8Quantizer { min, step }
    }

    /// Reassemble from persisted parts.
    pub fn from_parts(min: Vec<f32>, step: Vec<f32>) -> Sq8Quantizer {
        debug_assert_eq!(min.len(), step.len());
        Sq8Quantizer { min, step }
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        self.min.len()
    }

    /// Bytes per code row (`d`).
    pub fn code_len(&self) -> usize {
        self.min.len()
    }

    /// Per-dimension offsets (persistence).
    pub fn min(&self) -> &[f32] {
        &self.min
    }

    /// Per-dimension steps (persistence + the scan kernel).
    pub fn step(&self) -> &[f32] {
        &self.step
    }

    /// Resident bytes of the quantizer tables (min + step).
    pub fn table_bytes(&self) -> u64 {
        (2 * self.min.len() * 4) as u64
    }

    /// Encode one vector, appending `d` code bytes to `out`.  Values
    /// outside the trained range clamp to the nearest code — the rerank
    /// stage re-scores with exact f32 distances, so clamping only costs
    /// ranking quality, never correctness.
    pub fn encode_into(&self, x: &[f32], out: &mut Vec<u8>) {
        debug_assert_eq!(x.len(), self.min.len());
        out.extend((0..x.len()).map(|j| {
            let c = (x[j] - self.min[j]) / self.step[j];
            c.round().clamp(0.0, 255.0) as u8
        }));
    }

    /// Decode one code row (tests / diagnostics).
    pub fn decode(&self, code: &[u8]) -> Vec<f32> {
        code.iter()
            .enumerate()
            .map(|(j, &c)| self.min[j] + self.step[j] * c as f32)
            .collect()
    }

    /// The per-query residual `x - min`, computed once per query and
    /// shared across every candidate of the scan.
    pub fn residual(&self, x: &[f32]) -> Vec<f32> {
        x.iter().zip(&self.min).map(|(v, m)| v - m).collect()
    }
}

/// The fused SQ8 L2 kernel: `term(j) = (residual[j] - step[j]·code[j])²`
/// over one-byte codes — a [`DistanceKernel`], so it reuses the shared
/// early-abandon accumulation loop.
pub struct Sq8Terms<'a> {
    /// Per-query residual `x - min`.
    pub residual: &'a [f32],
    /// Per-dimension steps.
    pub step: &'a [f32],
    /// The candidate's code row.
    pub code: &'a [u8],
}

impl DistanceKernel for Sq8Terms<'_> {
    #[inline(always)]
    fn terms(&self) -> usize {
        self.code.len()
    }
    #[inline(always)]
    fn term(&self, j: usize) -> f32 {
        let t = self.residual[j] - self.step[j] * self.code[j] as f32;
        t * t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::search::{accumulate, distance::sq_l2};

    fn gaussian(seed: u64, d: usize, n: usize) -> Dataset {
        let mut rng = Rng::new(seed);
        let flat: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        Dataset::from_flat(d, flat).unwrap()
    }

    #[test]
    fn roundtrip_error_is_within_half_step() {
        let ds = gaussian(1, 12, 80);
        let q = Sq8Quantizer::train(&ds);
        let mut code = Vec::new();
        for v in ds.iter() {
            code.clear();
            q.encode_into(v, &mut code);
            let back = q.decode(&code);
            for j in 0..12 {
                assert!(
                    (back[j] - v[j]).abs() <= q.step()[j] * 0.5 + 1e-5,
                    "dim {j}: {} vs {}",
                    back[j],
                    v[j]
                );
            }
        }
    }

    #[test]
    fn kernel_matches_decoded_distance() {
        let ds = gaussian(2, 17, 40);
        let q = Sq8Quantizer::train(&ds);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..17).map(|_| rng.normal() as f32).collect();
        let residual = q.residual(&x);
        let mut code = Vec::new();
        for v in ds.iter() {
            code.clear();
            q.encode_into(v, &mut code);
            let via_kernel = accumulate(&Sq8Terms {
                residual: &residual,
                step: q.step(),
                code: &code,
            });
            let via_decode = sq_l2(&x, &q.decode(&code));
            assert!(
                (via_kernel - via_decode).abs() <= via_decode.abs() * 1e-4 + 1e-4,
                "{via_kernel} vs {via_decode}"
            );
        }
    }

    #[test]
    fn binary_01_data_encodes_to_extreme_codes() {
        let ds = Dataset::from_flat(3, vec![0., 1., 0., 1., 0., 1.]).unwrap();
        let q = Sq8Quantizer::train(&ds);
        let mut code = Vec::new();
        q.encode_into(&[1.0, 0.0, 1.0], &mut code);
        assert_eq!(code, vec![255, 0, 255]);
    }

    #[test]
    fn constant_dimension_is_exact() {
        let ds = Dataset::from_flat(2, vec![5., 1., 5., 3.]).unwrap();
        let q = Sq8Quantizer::train(&ds);
        let mut code = Vec::new();
        q.encode_into(&[5.0, 2.0], &mut code);
        assert_eq!(code[0], 0, "constant dim encodes to 0");
        assert_eq!(q.decode(&code)[0], 5.0, "and decodes exactly");
    }

    #[test]
    fn out_of_range_values_clamp() {
        let ds = Dataset::from_flat(1, vec![0., 1.]).unwrap();
        let q = Sq8Quantizer::train(&ds);
        let mut code = Vec::new();
        q.encode_into(&[100.0], &mut code);
        q.encode_into(&[-100.0], &mut code);
        assert_eq!(code, vec![255, 0]);
    }
}
