//! Scalar 8-bit quantization (SQ8): per-dimension affine codes.
//!
//! Training learns one `(min, step)` pair per dimension over the
//! database; a coordinate is stored as
//! `code = round((x - min) / step)` clamped to `0..=255` (one byte), and
//! decodes to `min + step · code`.  The scan distance is computed in the
//! **integer domain**: the query is encoded with the same quantizer once
//! per query (`qcode`), and the per-candidate kernel is
//! `Σ_j ((qcode_j − code_j)² as f32) · step_j²` — the byte difference
//! squared is exact in `i32` and in the `i32 → f32` convert, leaving one
//! f32 multiply per term, which scalar and SIMD backends perform
//! identically (the kernel lives in [`crate::search::kernels`]).  The
//! approximate distance equals the squared L2 between the two decoded
//! vectors up to decode rounding; the exact rerank stage absorbs the
//! difference, as it already absorbs quantization error.

use crate::data::dataset::Dataset;

/// Trained per-dimension affine 8-bit quantizer.
#[derive(Debug, Clone, PartialEq)]
pub struct Sq8Quantizer {
    /// Per-dimension offset (the observed minimum).
    min: Vec<f32>,
    /// Per-dimension step `(max - min) / 255`, forced positive so a
    /// constant dimension encodes to code 0 and decodes exactly.
    step: Vec<f32>,
    /// Per-dimension squared steps (`step[j]²`), precomputed once at
    /// train/load for the integer-domain scan kernel.
    step2: Vec<f32>,
}

impl Sq8Quantizer {
    /// Learn per-dimension ranges over `data` (must be non-empty; the
    /// index guarantees `n ≥ 1`).
    pub fn train(data: &Dataset) -> Sq8Quantizer {
        let d = data.dim();
        if data.is_empty() {
            // degenerate but total: identity-ish ranges, every code 0
            return Sq8Quantizer::from_parts(vec![0.0; d], vec![1.0; d]);
        }
        let mut min = vec![f32::INFINITY; d];
        let mut max = vec![f32::NEG_INFINITY; d];
        for v in data.iter() {
            for j in 0..d {
                if v[j] < min[j] {
                    min[j] = v[j];
                }
                if v[j] > max[j] {
                    max[j] = v[j];
                }
            }
        }
        let step = (0..d)
            .map(|j| {
                let s = (max[j] - min[j]) / 255.0;
                if s > 0.0 {
                    s
                } else {
                    1.0 // constant dimension: every code is 0
                }
            })
            .collect();
        Sq8Quantizer::from_parts(min, step)
    }

    /// Reassemble from persisted parts (`step2` is derived, not
    /// persisted).
    pub fn from_parts(min: Vec<f32>, step: Vec<f32>) -> Sq8Quantizer {
        debug_assert_eq!(min.len(), step.len());
        let step2 = step.iter().map(|s| s * s).collect();
        Sq8Quantizer { min, step, step2 }
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        self.min.len()
    }

    /// Bytes per code row (`d`).
    pub fn code_len(&self) -> usize {
        self.min.len()
    }

    /// Per-dimension offsets (persistence).
    pub fn min(&self) -> &[f32] {
        &self.min
    }

    /// Per-dimension steps (persistence).
    pub fn step(&self) -> &[f32] {
        &self.step
    }

    /// Per-dimension squared steps — the integer-domain scan kernel's
    /// weight table (see [`crate::search::kernels::Sq8Terms`]).
    pub fn step2(&self) -> &[f32] {
        &self.step2
    }

    /// Resident bytes of the quantizer tables (min + step).
    pub fn table_bytes(&self) -> u64 {
        (2 * self.min.len() * 4) as u64
    }

    /// Encode one vector, appending `d` code bytes to `out`.  Values
    /// outside the trained range clamp to the nearest code — the rerank
    /// stage re-scores with exact f32 distances, so clamping only costs
    /// ranking quality, never correctness.
    pub fn encode_into(&self, x: &[f32], out: &mut Vec<u8>) {
        debug_assert_eq!(x.len(), self.min.len());
        out.extend((0..x.len()).map(|j| {
            let c = (x[j] - self.min[j]) / self.step[j];
            c.round().clamp(0.0, 255.0) as u8
        }));
    }

    /// Decode one code row (tests / diagnostics).
    pub fn decode(&self, code: &[u8]) -> Vec<f32> {
        code.iter()
            .enumerate()
            .map(|(j, &c)| self.min[j] + self.step[j] * c as f32)
            .collect()
    }

    /// Encode the query for the integer-domain scan: the same clamped
    /// affine encoding as the database codes, computed once per query
    /// and shared across every candidate of the scan.
    pub fn encode_query(&self, x: &[f32]) -> Vec<u8> {
        let mut qcode = Vec::with_capacity(x.len());
        self.encode_into(x, &mut qcode);
        qcode
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::search::{distance::sq_l2, Kernels};

    fn gaussian(seed: u64, d: usize, n: usize) -> Dataset {
        let mut rng = Rng::new(seed);
        let flat: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        Dataset::from_flat(d, flat).unwrap()
    }

    #[test]
    fn roundtrip_error_is_within_half_step() {
        let ds = gaussian(1, 12, 80);
        let q = Sq8Quantizer::train(&ds);
        let mut code = Vec::new();
        for v in ds.iter() {
            code.clear();
            q.encode_into(v, &mut code);
            let back = q.decode(&code);
            for j in 0..12 {
                assert!(
                    (back[j] - v[j]).abs() <= q.step()[j] * 0.5 + 1e-5,
                    "dim {j}: {} vs {}",
                    back[j],
                    v[j]
                );
            }
        }
    }

    #[test]
    fn kernel_matches_decoded_distance() {
        // the integer-domain kernel equals the squared L2 between the
        // two *decoded* vectors, up to decode rounding: both measure
        // Σ (step·(qcode − code))² — the kernel without materializing
        // the decode
        let ds = gaussian(2, 17, 40);
        let q = Sq8Quantizer::train(&ds);
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..17).map(|_| rng.normal() as f32).collect();
        let qcode = q.encode_query(&x);
        let kernels = Kernels::scalar();
        let mut code = Vec::new();
        for v in ds.iter() {
            code.clear();
            q.encode_into(v, &mut code);
            let via_kernel = kernels.sq8(&qcode, &code, q.step2());
            let via_decode = sq_l2(&q.decode(&qcode), &q.decode(&code));
            assert!(
                (via_kernel - via_decode).abs() <= via_decode.abs() * 1e-4 + 1e-4,
                "{via_kernel} vs {via_decode}"
            );
        }
    }

    #[test]
    fn query_encoding_shares_the_database_encoder() {
        let ds = gaussian(4, 9, 30);
        let q = Sq8Quantizer::train(&ds);
        let x = ds.get(5);
        let mut via_encode_into = Vec::new();
        q.encode_into(x, &mut via_encode_into);
        assert_eq!(q.encode_query(x), via_encode_into);
        assert_eq!(q.step2().len(), 9);
        for j in 0..9 {
            assert_eq!(q.step2()[j], q.step()[j] * q.step()[j]);
        }
    }

    #[test]
    fn binary_01_data_encodes_to_extreme_codes() {
        let ds = Dataset::from_flat(3, vec![0., 1., 0., 1., 0., 1.]).unwrap();
        let q = Sq8Quantizer::train(&ds);
        let mut code = Vec::new();
        q.encode_into(&[1.0, 0.0, 1.0], &mut code);
        assert_eq!(code, vec![255, 0, 255]);
    }

    #[test]
    fn constant_dimension_is_exact() {
        let ds = Dataset::from_flat(2, vec![5., 1., 5., 3.]).unwrap();
        let q = Sq8Quantizer::train(&ds);
        let mut code = Vec::new();
        q.encode_into(&[5.0, 2.0], &mut code);
        assert_eq!(code[0], 0, "constant dim encodes to 0");
        assert_eq!(q.decode(&code)[0], 5.0, "and decodes exactly");
    }

    #[test]
    fn out_of_range_values_clamp() {
        let ds = Dataset::from_flat(1, vec![0., 1.]).unwrap();
        let q = Sq8Quantizer::train(&ds);
        let mut code = Vec::new();
        q.encode_into(&[100.0], &mut code);
        q.encode_into(&[-100.0], &mut code);
        assert_eq!(code, vec![255, 0]);
    }
}
