//! Product quantization (PQ) with asymmetric distance computation (ADC).
//!
//! The vector is split into `m` contiguous subspaces of `d/m` dimensions
//! each; every subspace gets its own k-means codebook of `2^bits`
//! centroids (trained via [`crate::baseline::kmeans`], the same
//! coarse-quantizer substrate the IVF baseline uses).  A vector is
//! stored as `m` one-byte centroid ids.
//!
//! Queries are never quantized: per query, an ADC lookup table holds the
//! *exact* squared distance between each query subvector and each
//! centroid, built once and shared across the whole class-major scan, so
//! a candidate's approximate distance is `m` table lookups — summed
//! through the kernel dispatch ([`crate::search::kernels`]), since every
//! cell is a squared distance and therefore non-negative.
//!
//! The table rows are padded to a power-of-two stride (`1 << shift`
//! floats, `shift = ceil(log2(n_centroids))`): subspace `s`'s cell for
//! centroid `c` sits at `(s << shift) | c`, so the address is a shift
//! and an OR — no multiply, and the vector backends read cells as plain
//! sequential L1 loads, no gather instruction.  Pad cells are `0.0` and
//! are never addressed by in-range codes (enforced at load by
//! [`crate::quant::QuantIndex::from_parts`]).

use crate::baseline::kmeans::kmeans;
use crate::data::dataset::Dataset;
use crate::data::rng::Rng;
use crate::error::{Error, Result};
use crate::search::distance::sq_l2;

/// Trained product quantizer.
#[derive(Debug, Clone, PartialEq)]
pub struct PqQuantizer {
    dim: usize,
    m: usize,
    sub_dim: usize,
    bits: usize,
    /// Centroids actually trained per subspace: `min(2^bits, n)` — a
    /// tiny database cannot support a full codebook.
    n_centroids: usize,
    /// `[m, n_centroids, sub_dim]` row-major centroid table.
    codebooks: Vec<f32>,
}

impl PqQuantizer {
    /// Train per-subspace codebooks over `data`.  Deterministic given
    /// the rng seed (k-means++ seeding and Lloyd iterations consume the
    /// rng in a fixed order).
    pub fn train(data: &Dataset, m: usize, bits: usize, rng: &mut Rng) -> Result<PqQuantizer> {
        let d = data.dim();
        if m == 0 || m > d || d % m != 0 {
            return Err(Error::Config(format!("pq m {m} must divide the dimension {d}")));
        }
        if bits == 0 || bits > 8 {
            return Err(Error::Config(format!("pq bits {bits} must be in 1..=8")));
        }
        if data.is_empty() {
            return Err(Error::Config("cannot train pq codebooks on no data".into()));
        }
        let sub_dim = d / m;
        let n_centroids = (1usize << bits).min(data.len());
        let mut codebooks = Vec::with_capacity(m * n_centroids * sub_dim);
        for s in 0..m {
            // materialize the subspace columns as an (n × sub_dim) dataset
            let mut flat = Vec::with_capacity(data.len() * sub_dim);
            for v in data.iter() {
                flat.extend_from_slice(&v[s * sub_dim..(s + 1) * sub_dim]);
            }
            let sub = Dataset::from_flat(sub_dim, flat)?;
            let km = kmeans(&sub, n_centroids, 25, rng)?;
            codebooks.extend_from_slice(&km.centroids);
        }
        Ok(PqQuantizer { dim: d, m, sub_dim, bits, n_centroids, codebooks })
    }

    /// Reassemble from persisted parts.
    pub fn from_parts(
        dim: usize,
        m: usize,
        bits: usize,
        n_centroids: usize,
        codebooks: Vec<f32>,
    ) -> Result<PqQuantizer> {
        if m == 0 || m > dim || dim % m != 0 {
            return Err(Error::Data(format!("pq m {m} must divide the dimension {dim}")));
        }
        if n_centroids == 0 || n_centroids > 256 {
            return Err(Error::Data(format!(
                "pq centroid count {n_centroids} must be in 1..=256"
            )));
        }
        let sub_dim = dim / m;
        if codebooks.len() != m * n_centroids * sub_dim {
            return Err(Error::Data(format!(
                "pq codebook length {} != m·k·sub_dim = {}",
                codebooks.len(),
                m * n_centroids * sub_dim
            )));
        }
        Ok(PqQuantizer { dim, m, sub_dim, bits, n_centroids, codebooks })
    }

    /// Vector dimensionality `d`.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of subspaces `m` (= bytes per code row).
    pub fn m(&self) -> usize {
        self.m
    }

    /// Dimensions per subspace (`d / m`).
    pub fn sub_dim(&self) -> usize {
        self.sub_dim
    }

    /// Configured bits per code.
    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Centroids actually trained per subspace.
    pub fn n_centroids(&self) -> usize {
        self.n_centroids
    }

    /// Bytes per code row (`m`).
    pub fn code_len(&self) -> usize {
        self.m
    }

    /// The `[m, n_centroids, sub_dim]` centroid table (persistence).
    pub fn codebooks(&self) -> &[f32] {
        &self.codebooks
    }

    /// Resident bytes of the codebooks.
    pub fn table_bytes(&self) -> u64 {
        (self.codebooks.len() * 4) as u64
    }

    /// Centroid `c` of subspace `s`.
    fn centroid(&self, s: usize, c: usize) -> &[f32] {
        let base = (s * self.n_centroids + c) * self.sub_dim;
        &self.codebooks[base..base + self.sub_dim]
    }

    /// Encode one vector, appending `m` code bytes to `out` (nearest
    /// centroid per subspace; distance ties resolve to the smaller
    /// centroid id, so encoding is deterministic).
    pub fn encode_into(&self, x: &[f32], out: &mut Vec<u8>) {
        debug_assert_eq!(x.len(), self.dim);
        for s in 0..self.m {
            let sub = &x[s * self.sub_dim..(s + 1) * self.sub_dim];
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for c in 0..self.n_centroids {
                let dist = sq_l2(sub, self.centroid(s, c));
                if dist < best_d {
                    best_d = dist;
                    best = c;
                }
            }
            out.push(best as u8);
        }
    }

    /// Decode one code row to the centroid concatenation (tests).
    pub fn decode(&self, code: &[u8]) -> Vec<f32> {
        let mut v = Vec::with_capacity(self.dim);
        for (s, &c) in code.iter().enumerate() {
            v.extend_from_slice(self.centroid(s, c as usize));
        }
        v
    }

    /// log2 of the padded ADC row stride: the smallest power of two
    /// holding `n_centroids` cells.
    pub fn stride_shift(&self) -> u32 {
        self.n_centroids.next_power_of_two().trailing_zeros()
    }

    /// Build the per-query ADC table in the padded layout (see the
    /// module docs): cell `(s << shift) | c` is the exact squared
    /// distance between the query's subvector `s` and centroid `c`,
    /// with `shift = ` [`Self::stride_shift`]; pad cells are `0.0`.
    /// `m · n_centroids · sub_dim` work, paid once per query per batch
    /// and amortized over every scanned candidate.
    pub fn adc_table(&self, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.dim);
        let shift = self.stride_shift();
        let mut lut = vec![0f32; self.m << shift];
        for s in 0..self.m {
            let sub = &x[s * self.sub_dim..(s + 1) * self.sub_dim];
            for c in 0..self.n_centroids {
                lut[(s << shift) | c] = sq_l2(sub, self.centroid(s, c));
            }
        }
        lut
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::Kernels;

    fn gaussian(seed: u64, d: usize, n: usize) -> Dataset {
        let mut rng = Rng::new(seed);
        let flat: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        Dataset::from_flat(d, flat).unwrap()
    }

    #[test]
    fn trains_and_encodes() {
        let ds = gaussian(1, 12, 100);
        let mut rng = Rng::new(2);
        let pq = PqQuantizer::train(&ds, 3, 4, &mut rng).unwrap();
        assert_eq!(pq.sub_dim(), 4);
        assert_eq!(pq.n_centroids(), 16);
        assert_eq!(pq.codebooks().len(), 3 * 16 * 4);
        let mut code = Vec::new();
        pq.encode_into(ds.get(0), &mut code);
        assert_eq!(code.len(), 3);
        assert!(code.iter().all(|&c| (c as usize) < 16));
    }

    #[test]
    fn encode_picks_nearest_centroid() {
        let ds = gaussian(3, 8, 120);
        let mut rng = Rng::new(4);
        let pq = PqQuantizer::train(&ds, 2, 3, &mut rng).unwrap();
        let mut code = Vec::new();
        for v in ds.iter().take(20) {
            code.clear();
            pq.encode_into(v, &mut code);
            for s in 0..2 {
                let sub = &v[s * 4..(s + 1) * 4];
                let chosen = sq_l2(sub, pq.centroid(s, code[s] as usize));
                for c in 0..pq.n_centroids() {
                    assert!(
                        chosen <= sq_l2(sub, pq.centroid(s, c)) + 1e-5,
                        "subspace {s}: centroid {c} beats chosen {}",
                        code[s]
                    );
                }
            }
        }
    }

    #[test]
    fn adc_distance_equals_decoded_distance() {
        let ds = gaussian(5, 16, 80);
        let mut rng = Rng::new(6);
        let pq = PqQuantizer::train(&ds, 4, 4, &mut rng).unwrap();
        let x: Vec<f32> = (0..16).map(|j| (j as f32 * 0.3).sin()).collect();
        let lut = pq.adc_table(&x);
        assert_eq!(lut.len(), pq.m() << pq.stride_shift());
        let mut code = Vec::new();
        for v in ds.iter().take(20) {
            code.clear();
            pq.encode_into(v, &mut code);
            let via_adc = Kernels::scalar().adc(&lut, pq.stride_shift(), &code);
            // ADC sums per-subspace distances — exactly the squared
            // distance to the decoded (centroid-concatenated) vector
            let via_decode = sq_l2(&x, &pq.decode(&code));
            assert!(
                (via_adc - via_decode).abs() <= via_decode.abs() * 1e-4 + 1e-4,
                "{via_adc} vs {via_decode}"
            );
        }
    }

    #[test]
    fn tiny_database_clamps_codebook_size() {
        let ds = gaussian(7, 4, 3);
        let mut rng = Rng::new(8);
        let pq = PqQuantizer::train(&ds, 2, 8, &mut rng).unwrap();
        assert_eq!(pq.n_centroids(), 3, "k clamps to n");
        // non-power-of-two codebook pads its ADC rows to the next power
        assert_eq!(pq.stride_shift(), 2);
        let lut = pq.adc_table(ds.get(0));
        assert_eq!(lut.len(), 2 << 2);
        assert_eq!(lut[3], 0.0, "pad cell never addressed by codes 0..3");
        assert_eq!(lut[7], 0.0, "pad cell never addressed by codes 0..3");
    }

    #[test]
    fn bad_shapes_rejected() {
        let ds = gaussian(9, 10, 20);
        let mut rng = Rng::new(10);
        assert!(PqQuantizer::train(&ds, 3, 4, &mut rng).is_err(), "m ∤ d");
        assert!(PqQuantizer::train(&ds, 0, 4, &mut rng).is_err());
        assert!(PqQuantizer::train(&ds, 2, 0, &mut rng).is_err());
        assert!(PqQuantizer::train(&ds, 2, 9, &mut rng).is_err());
        assert!(PqQuantizer::from_parts(10, 2, 4, 16, vec![0.0; 7]).is_err());
        assert!(PqQuantizer::from_parts(10, 2, 4, 300, vec![0.0; 5 * 300 * 2]).is_err());
    }

    #[test]
    fn from_parts_roundtrips() {
        let ds = gaussian(11, 8, 60);
        let mut rng = Rng::new(12);
        let pq = PqQuantizer::train(&ds, 2, 4, &mut rng).unwrap();
        let back = PqQuantizer::from_parts(
            pq.dim(),
            pq.m(),
            pq.bits(),
            pq.n_centroids(),
            pq.codebooks().to_vec(),
        )
        .unwrap();
        assert_eq!(back, pq);
    }
}
