//! The exact rerank stage of the two-stage compressed scan.
//!
//! Stage 1 (in [`crate::index::AmIndex`]'s scan paths) ranks every
//! scanned candidate by its *approximate* compressed distance, keeping
//! the best `r` per query in a `TopK(r)` accumulator.  Stage 2 — this
//! module — re-scores those survivors with the exact f32 metric and
//! selects the final top-`k` with the very same `(distance, id)` rule
//! as the full-precision scan.
//!
//! Why `r = everything-scanned` (`rerank = 0`) is bitwise-exact: the
//! reported distances all come from [`crate::search::distance_pruned`]
//! (bitwise `sq_l2` for kept candidates, and abandoned candidates
//! provably cannot enter the top-k), and the `TopK` selection is
//! invariant to candidate order under the total `(distance, id)` order.
//! So whenever the survivor set contains the true top-`k`, the result is
//! bit-for-bit the exact scan's — and at `rerank = 0` the survivor set
//! is *all* scanned candidates, which always contains it.

use crate::search::{Kernels, Metric, Neighbor, TopK};
use crate::store::RowReader;

/// Exact-rerank the stage-1 survivors: `survivors` are `(approx_dist,
/// id)` pairs (any order; stage 1 hands them ascending).  Exact rows
/// come through `rows` — the resident dataset, or the paged extent
/// cache (survivors of one class share its single fetch; a row a
/// poisoned paged store cannot produce is skipped, and the serving
/// layer fails the request from the stored error afterwards).  Returns
/// the final neighbors plus the number of exact distance evaluations
/// (the `rerank_ops` unit is this count times `d`).
pub(crate) fn rerank_exact(
    metric: Metric,
    x: &[f32],
    rows: RowReader<'_>,
    survivors: Vec<(f32, u32)>,
    k: usize,
    kernels: Kernels,
) -> (Vec<Neighbor>, usize) {
    let reranked = survivors.len();
    let mut acc = TopK::new(k.max(1));
    for (_, vid) in survivors {
        // early abandoning against the current exact k-th best: kept
        // distances are bitwise sq_l2, abandoned ones provably lose
        if let Some(Some(dist)) = rows.with_row(vid as usize, |v| {
            kernels.distance_pruned(metric, x, v, acc.bound())
        }) {
            acc.push(dist, vid);
        }
    }
    (acc.into_neighbors(), reranked)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::data::rng::Rng;
    use crate::search::distance::sq_l2;

    fn gaussian(seed: u64, d: usize, n: usize) -> Dataset {
        let mut rng = Rng::new(seed);
        let flat: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        Dataset::from_flat(d, flat).unwrap()
    }

    #[test]
    fn rerank_over_all_candidates_is_the_exact_topk() {
        let ds = gaussian(1, 8, 50);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..8).map(|_| rng.normal() as f32).collect();
        // garbage approximate keys: the rerank must not care
        let survivors: Vec<(f32, u32)> =
            (0..50).map(|i| ((50 - i) as f32, i as u32)).collect();
        let (got, reranked) = rerank_exact(
            Metric::SqL2,
            &x,
            RowReader::Dataset(&ds),
            survivors,
            3,
            Kernels::select(),
        );
        assert_eq!(reranked, 50);
        let mut want: Vec<(f32, u32)> =
            (0..50).map(|i| (sq_l2(&x, ds.get(i)), i as u32)).collect();
        want.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for (nb, (wd, wi)) in got.iter().zip(want.iter().take(3)) {
            assert_eq!(nb.id, *wi);
            assert_eq!(nb.distance.to_bits(), wd.to_bits());
        }
    }

    #[test]
    fn empty_survivors_give_empty_neighbors() {
        let ds = gaussian(3, 4, 10);
        let (got, reranked) = rerank_exact(
            Metric::SqL2,
            &[0.0; 4],
            RowReader::Dataset(&ds),
            Vec::new(),
            5,
            Kernels::scalar(),
        );
        assert!(got.is_empty());
        assert_eq!(reranked, 0);
    }
}
