//! Quantized candidate scan — the *dimension* axis of the trade-off.
//!
//! The paper attacks the cardinal axis (AM polling prunes which classes
//! are scanned) and explicitly leaves "reducing the dimension of vectors
//! using quantization techniques or hashing" to complementary work.
//! This subsystem composes both: polled classes are scanned over a
//! compressed in-memory representation, and only the best `rerank`
//! compressed candidates per query are re-scored with the exact f32
//! metric — the standard compressed-scan + exact-rerank recipe of
//! at-scale ANN systems.
//!
//! Two representations:
//!
//! * [`scalar`] — per-dimension affine 8-bit quantization (SQ8): one
//!   `(min, step)` pair per dimension, one byte per coordinate, and a
//!   fused integer-code L2 kernel (4× memory reduction).
//! * [`pq`] — product quantization: the vector is split into `m`
//!   subspaces, each summarized by a per-subspace k-means codebook
//!   (`2^bits` centroids, trained via [`crate::baseline::kmeans`]);
//!   distances are read from a per-query asymmetric-distance (ADC)
//!   lookup table built once and shared across the class-major scan
//!   (`4·d/m`× memory reduction at 8 bits).
//!
//! Both distances dispatch through [`crate::search::kernels`] (scalar
//! term producers implement [`crate::search::DistanceKernel`]; SIMD
//! backends are bitwise-equal), sharing the f32 scan's early-abandon
//! probe cadence and tie contract.
//!
//! The correctness anchor: the approximate distances only *rank*
//! candidates — every reported distance comes from the exact rerank
//! stage ([`rerank`]), bitwise-identical to the full-precision scan for
//! the candidates it keeps.  With `rerank = 0` ("rerank everything
//! scanned") the two-stage scan degenerates to the exact scan: same
//! ids, bitwise-same distances (pinned by
//! `prop_quant_rerank_full_matches_exact`).

pub mod pq;
pub mod rerank;
pub mod scalar;

pub use pq::PqQuantizer;
pub use scalar::Sq8Quantizer;

use crate::data::dataset::Dataset;
use crate::data::rng::Rng;
use crate::error::{Error, Result};
use crate::search::Kernels;

/// Deterministic seed for PQ codebook training: retraining over the same
/// data always yields the same codebooks (k-means is deterministic given
/// the seed), so an index rebuilt from parts matches its persisted form.
const PQ_TRAIN_SEED: u64 = 0x9A11_A5C0;

/// Precision of the candidate-scan stage.  `rerank` is the number of
/// best compressed candidates per query re-scored with the exact f32
/// metric (`0` = rerank every scanned candidate, which makes the
/// quantized scan bitwise-identical to [`ScanPrecision::Exact`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScanPrecision {
    /// Full-precision f32 scan (the historical behavior).
    #[default]
    Exact,
    /// Scalar 8-bit scan + exact rerank.
    Sq8 {
        /// Compressed candidates kept for exact rerank (0 = all).
        rerank: usize,
    },
    /// Product-quantized ADC scan + exact rerank.
    Pq {
        /// Number of subspaces (must divide the dimension).
        m: usize,
        /// Bits per subspace code (1..=8; `2^bits` centroids).
        bits: usize,
        /// Compressed candidates kept for exact rerank (0 = all).
        rerank: usize,
    },
}

impl std::fmt::Display for ScanPrecision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScanPrecision::Exact => write!(f, "exact"),
            ScanPrecision::Sq8 { rerank } => write!(f, "sq8(rerank={rerank})"),
            ScanPrecision::Pq { m, bits, rerank } => {
                write!(f, "pq(m={m},bits={bits},rerank={rerank})")
            }
        }
    }
}

impl ScanPrecision {
    /// Short mode label ("exact" | "sq8" | "pq") — the `quant.mode`
    /// STATS field.
    pub fn mode(&self) -> &'static str {
        match self {
            ScanPrecision::Exact => "exact",
            ScanPrecision::Sq8 { .. } => "sq8",
            ScanPrecision::Pq { .. } => "pq",
        }
    }

    /// The rerank budget (0 = all; also 0 for `Exact`, which has no
    /// rerank stage).
    pub fn rerank(&self) -> usize {
        match self {
            ScanPrecision::Exact => 0,
            ScanPrecision::Sq8 { rerank } => *rerank,
            ScanPrecision::Pq { rerank, .. } => *rerank,
        }
    }

    /// Replace the rerank budget (no-op for `Exact`).  Lets evals and
    /// benches sweep `rerank` without retraining codebooks.
    pub fn with_rerank(self, rerank: usize) -> ScanPrecision {
        match self {
            ScanPrecision::Exact => ScanPrecision::Exact,
            ScanPrecision::Sq8 { .. } => ScanPrecision::Sq8 { rerank },
            ScanPrecision::Pq { m, bits, .. } => ScanPrecision::Pq { m, bits, rerank },
        }
    }

    /// Dimension-independent parameter checks (what a config file can
    /// verify before any data exists).
    pub fn validate_params(&self) -> Result<()> {
        if let ScanPrecision::Pq { m, bits, .. } = self {
            if *m == 0 {
                return Err(Error::Config("pq m must be > 0".into()));
            }
            if *bits == 0 || *bits > 8 {
                return Err(Error::Config(format!(
                    "pq bits {bits} must be in 1..=8"
                )));
            }
        }
        Ok(())
    }

    /// Full validation against a concrete vector dimension.
    pub fn validate_for_dim(&self, dim: usize) -> Result<()> {
        self.validate_params()?;
        if let ScanPrecision::Pq { m, .. } = self {
            if *m > dim || dim % m != 0 {
                return Err(Error::Config(format!(
                    "pq m {m} must divide the dimension {dim}"
                )));
            }
        }
        Ok(())
    }
}

/// Memory footprint of an index's candidate-scan representation: the
/// full-precision member-matrix bytes versus what the scan actually
/// keeps resident.  For an exact index the two are equal.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IndexFootprint {
    /// f32 member-matrix bytes (`n · d · 4`).
    pub bytes: u64,
    /// Bytes of the scanned representation: codes + codebooks/tables for
    /// a quantized index, `bytes` for an exact one.
    pub compressed_bytes: u64,
}

impl IndexFootprint {
    /// `compressed_bytes / bytes` (1.0 for an exact index, 0 when empty).
    pub fn ratio(&self) -> f64 {
        if self.bytes == 0 {
            0.0
        } else {
            self.compressed_bytes as f64 / self.bytes as f64
        }
    }

    /// Accumulate another footprint (cluster tier: sum over shards).
    pub fn add(&mut self, other: IndexFootprint) {
        self.bytes += other.bytes;
        self.compressed_bytes += other.compressed_bytes;
    }
}

/// The trained quantizer variant behind a [`QuantIndex`].
#[derive(Debug, Clone, PartialEq)]
pub enum Quantizer {
    /// Per-dimension affine 8-bit.
    Sq8(Sq8Quantizer),
    /// Product quantization.
    Pq(PqQuantizer),
}

/// Compressed companion of an [`crate::index::AmIndex`]: one fixed-width
/// code row per stored vector (global-id order, so class member lists
/// index it directly), plus the trained quantizer and the rerank budget.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantIndex {
    quantizer: Quantizer,
    /// Row-major codes, `code_len` bytes per vector.
    codes: Vec<u8>,
    code_len: usize,
    rerank: usize,
}

impl QuantIndex {
    /// Train a quantizer for `precision` over `data` and encode every
    /// vector.  Returns `None` for [`ScanPrecision::Exact`].
    /// Deterministic: the same data and precision always produce the
    /// same codebooks and codes (PQ training is seeded by
    /// [`PQ_TRAIN_SEED`]).
    pub fn train(data: &Dataset, precision: ScanPrecision) -> Result<Option<QuantIndex>> {
        precision.validate_for_dim(data.dim())?;
        let (quantizer, rerank) = match precision {
            ScanPrecision::Exact => return Ok(None),
            ScanPrecision::Sq8 { rerank } => {
                (Quantizer::Sq8(Sq8Quantizer::train(data)), rerank)
            }
            ScanPrecision::Pq { m, bits, rerank } => {
                let mut rng = Rng::new(PQ_TRAIN_SEED);
                (Quantizer::Pq(PqQuantizer::train(data, m, bits, &mut rng)?), rerank)
            }
        };
        let code_len = match &quantizer {
            Quantizer::Sq8(q) => q.code_len(),
            Quantizer::Pq(q) => q.code_len(),
        };
        let mut codes = Vec::with_capacity(data.len() * code_len);
        for v in data.iter() {
            match &quantizer {
                Quantizer::Sq8(q) => q.encode_into(v, &mut codes),
                Quantizer::Pq(q) => q.encode_into(v, &mut codes),
            }
        }
        Ok(Some(QuantIndex { quantizer, codes, code_len, rerank }))
    }

    /// Reassemble from persisted parts (see [`crate::index::persist`]).
    /// Every PQ code byte is range-checked against the codebook here —
    /// a corrupt-but-checksummed (or foreign-writer) artifact must fail
    /// load with a typed error, never index past a query's ADC table
    /// inside a serving worker.
    pub fn from_parts(
        quantizer: Quantizer,
        codes: Vec<u8>,
        rerank: usize,
    ) -> Result<QuantIndex> {
        let code_len = match &quantizer {
            Quantizer::Sq8(q) => q.code_len(),
            Quantizer::Pq(q) => q.code_len(),
        };
        if code_len == 0 || codes.len() % code_len != 0 {
            return Err(Error::Data(format!(
                "quant codes length {} not a multiple of code width {code_len}",
                codes.len()
            )));
        }
        if let Quantizer::Pq(q) = &quantizer {
            let k = q.n_centroids();
            if let Some(pos) = codes.iter().position(|&c| c as usize >= k) {
                return Err(Error::Data(format!(
                    "pq code byte {} at offset {pos} out of range \
                     (codebook has {k} centroids)",
                    codes[pos]
                )));
            }
        }
        Ok(QuantIndex { quantizer, codes, code_len, rerank })
    }

    /// Encode and append one vector (the online-insert path).
    pub fn push(&mut self, x: &[f32]) {
        match &self.quantizer {
            Quantizer::Sq8(q) => q.encode_into(x, &mut self.codes),
            Quantizer::Pq(q) => q.encode_into(x, &mut self.codes),
        }
    }

    /// Number of encoded vectors.
    pub fn len(&self) -> usize {
        self.codes.len() / self.code_len
    }

    /// True when no vector has been encoded.
    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Code row of vector `id`.
    #[inline]
    pub fn code(&self, id: usize) -> &[u8] {
        &self.codes[id * self.code_len..(id + 1) * self.code_len]
    }

    /// The full code buffer (persistence).
    pub fn codes(&self) -> &[u8] {
        &self.codes
    }

    /// Bytes per code row.
    pub fn code_len(&self) -> usize {
        self.code_len
    }

    /// The trained quantizer (persistence / inspection).
    pub fn quantizer(&self) -> &Quantizer {
        &self.quantizer
    }

    /// The rerank budget (0 = rerank everything scanned).
    pub fn rerank(&self) -> usize {
        self.rerank
    }

    /// Change the rerank budget without retraining.
    pub fn set_rerank(&mut self, rerank: usize) {
        self.rerank = rerank;
    }

    /// Mode label ("sq8" | "pq").
    pub fn mode(&self) -> &'static str {
        match &self.quantizer {
            Quantizer::Sq8(_) => "sq8",
            Quantizer::Pq(_) => "pq",
        }
    }

    /// Reconstruct the [`ScanPrecision`] this index implements.
    pub fn precision(&self) -> ScanPrecision {
        match &self.quantizer {
            Quantizer::Sq8(_) => ScanPrecision::Sq8 { rerank: self.rerank },
            Quantizer::Pq(q) => ScanPrecision::Pq {
                m: q.m(),
                bits: q.bits(),
                rerank: self.rerank,
            },
        }
    }

    /// Elementary ops per candidate of the compressed scan (`d` for SQ8,
    /// `m` table lookups for PQ) — the `compressed_ops` unit.
    pub fn approx_unit_cost(&self) -> usize {
        self.code_len
    }

    /// Resident bytes of the compressed representation: all code rows
    /// plus the quantizer's tables (SQ8 min/step, PQ codebooks).
    pub fn compressed_bytes(&self) -> u64 {
        let table = match &self.quantizer {
            Quantizer::Sq8(q) => q.table_bytes(),
            Quantizer::Pq(q) => q.table_bytes(),
        };
        self.codes.len() as u64 + table
    }

    /// Build the per-query lookup structure shared across the whole
    /// class-major scan: the SQ8 encoded query, or the PQ ADC table
    /// (one exact subvector-to-centroid distance per `(subspace,
    /// centroid)` cell in the padded gather-free layout, computed once
    /// per query per batch).  `kernels` is the index's one-time-selected
    /// dispatch handle; every candidate distance of the scan goes
    /// through it.
    pub fn prepare(&self, x: &[f32], kernels: Kernels) -> QueryLut<'_> {
        match &self.quantizer {
            Quantizer::Sq8(q) => QueryLut::Sq8 {
                qcode: q.encode_query(x),
                step2: q.step2(),
                kernels,
            },
            Quantizer::Pq(q) => QueryLut::Pq {
                lut: q.adc_table(x),
                shift: q.stride_shift(),
                kernels,
            },
        }
    }
}

/// Per-query state of the compressed scan (see [`QuantIndex::prepare`]).
#[derive(Debug, Clone)]
pub enum QueryLut<'a> {
    /// SQ8 integer-domain: the per-candidate term is
    /// `((qcode[j] − code[j])² as f32) · step2[j]`.
    Sq8 {
        /// The query, encoded with the database quantizer.
        qcode: Vec<u8>,
        /// Per-dimension squared steps (borrowed from the quantizer).
        step2: &'a [f32],
        /// The index's kernel dispatch handle.
        kernels: Kernels,
    },
    /// PQ: `lut[(s << shift) | c]` = exact squared distance between the
    /// query's `s`-th subvector and centroid `c` (padded rows, see
    /// [`pq::PqQuantizer::adc_table`]).
    Pq {
        /// The padded `[m << shift]` ADC table.
        lut: Vec<f32>,
        /// log2 of the row stride.
        shift: u32,
        /// The index's kernel dispatch handle.
        kernels: Kernels,
    },
}

impl QueryLut<'_> {
    /// Approximate distance of one code row with early abandoning
    /// against `bound` (same contract as
    /// [`crate::search::distance_pruned`]: `None` iff strictly greater,
    /// kept values deterministic).
    #[inline]
    pub fn distance_pruned(&self, code: &[u8], bound: f32) -> Option<f32> {
        match self {
            QueryLut::Sq8 { qcode, step2, kernels } => {
                kernels.sq8_pruned(qcode, code, step2, bound)
            }
            QueryLut::Pq { lut, shift, kernels } => {
                kernels.adc_pruned(lut, *shift, code, bound)
            }
        }
    }

    /// Unpruned approximate distance (tests / diagnostics).
    pub fn distance(&self, code: &[u8]) -> f32 {
        self.distance_pruned(code, f32::INFINITY)
            .unwrap_or(f32::INFINITY)
    }
}

/// The effective rerank heap size for one query: `rerank = 0` means
/// every scanned candidate survives to the exact stage (the
/// equivalence-pin degenerate), and the budget can never usefully be
/// below `k` or above the candidate count.
pub fn effective_rerank(rerank: usize, k: usize, candidates: usize) -> usize {
    let r = if rerank == 0 { candidates } else { rerank.max(k) };
    r.clamp(1, candidates.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn dense(seed: u64, d: usize, n: usize) -> Dataset {
        let mut rng = Rng::new(seed);
        synthetic::dense_patterns(d, n, &mut rng)
    }

    #[test]
    fn exact_trains_to_none() {
        let ds = dense(1, 8, 10);
        assert!(QuantIndex::train(&ds, ScanPrecision::Exact).unwrap().is_none());
    }

    #[test]
    fn sq8_codes_have_one_byte_per_dim() {
        let ds = dense(2, 16, 40);
        let q = QuantIndex::train(&ds, ScanPrecision::Sq8 { rerank: 8 })
            .unwrap()
            .unwrap();
        assert_eq!(q.len(), 40);
        assert_eq!(q.code_len(), 16);
        assert_eq!(q.code(7).len(), 16);
        assert_eq!(q.mode(), "sq8");
        assert_eq!(q.rerank(), 8);
        assert_eq!(q.precision(), ScanPrecision::Sq8 { rerank: 8 });
        // codes (n·d) + min/step tables (2·d·4) — far below n·d·4
        assert_eq!(q.compressed_bytes(), (40 * 16 + 2 * 16 * 4) as u64);
    }

    #[test]
    fn pq_codes_have_one_byte_per_subspace() {
        let ds = dense(3, 16, 60);
        let p = ScanPrecision::Pq { m: 4, bits: 4, rerank: 0 };
        let q = QuantIndex::train(&ds, p).unwrap().unwrap();
        assert_eq!(q.code_len(), 4);
        assert_eq!(q.len(), 60);
        assert_eq!(q.mode(), "pq");
        assert_eq!(q.precision(), p);
        // 16 centroids × 4 dims × 4 subspaces of f32 + n·m code bytes
        assert_eq!(q.compressed_bytes(), (60 * 4 + 16 * 4 * 4 * 4) as u64);
    }

    #[test]
    fn training_is_deterministic() {
        let ds = dense(4, 8, 50);
        let p = ScanPrecision::Pq { m: 2, bits: 3, rerank: 5 };
        let a = QuantIndex::train(&ds, p).unwrap().unwrap();
        let b = QuantIndex::train(&ds, p).unwrap().unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn push_appends_a_code_row() {
        let ds = dense(5, 8, 20);
        let mut q = QuantIndex::train(&ds, ScanPrecision::Sq8 { rerank: 0 })
            .unwrap()
            .unwrap();
        let x: Vec<f32> = ds.get(3).to_vec();
        q.push(&x);
        assert_eq!(q.len(), 21);
        assert_eq!(q.code(20), q.code(3), "same vector, same code");
    }

    #[test]
    fn from_parts_rejects_out_of_range_pq_codes() {
        let ds = dense(6, 8, 40);
        let q = QuantIndex::train(&ds, ScanPrecision::Pq { m: 2, bits: 3, rerank: 0 })
            .unwrap()
            .unwrap();
        let quantizer = q.quantizer().clone();
        let mut codes = q.codes().to_vec();
        // valid bytes round-trip ...
        QuantIndex::from_parts(quantizer.clone(), codes.clone(), 0).unwrap();
        // ... a byte >= the codebook size (8 centroids at bits=3) does not
        codes[5] = 8;
        let err = QuantIndex::from_parts(quantizer, codes, 0).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn precision_validation() {
        assert!(ScanPrecision::Pq { m: 0, bits: 4, rerank: 0 }
            .validate_params()
            .is_err());
        assert!(ScanPrecision::Pq { m: 2, bits: 0, rerank: 0 }
            .validate_params()
            .is_err());
        assert!(ScanPrecision::Pq { m: 2, bits: 9, rerank: 0 }
            .validate_params()
            .is_err());
        assert!(ScanPrecision::Pq { m: 3, bits: 4, rerank: 0 }
            .validate_for_dim(8)
            .is_err());
        ScanPrecision::Pq { m: 4, bits: 8, rerank: 0 }
            .validate_for_dim(8)
            .unwrap();
        ScanPrecision::Sq8 { rerank: 0 }.validate_for_dim(3).unwrap();
        ScanPrecision::Exact.validate_for_dim(1).unwrap();
    }

    #[test]
    fn effective_rerank_rules() {
        // 0 = everything scanned
        assert_eq!(effective_rerank(0, 3, 100), 100);
        // never below k, never above the candidate count
        assert_eq!(effective_rerank(5, 10, 100), 10);
        assert_eq!(effective_rerank(500, 1, 100), 100);
        assert_eq!(effective_rerank(5, 1, 100), 5);
        // empty scans still need a positive heap
        assert_eq!(effective_rerank(0, 1, 0), 1);
    }

    #[test]
    fn footprint_ratio_and_add() {
        let mut fp = IndexFootprint { bytes: 400, compressed_bytes: 100 };
        assert!((fp.ratio() - 0.25).abs() < 1e-12);
        fp.add(IndexFootprint { bytes: 600, compressed_bytes: 150 });
        assert_eq!(fp, IndexFootprint { bytes: 1000, compressed_bytes: 250 });
        assert_eq!(IndexFootprint::default().ratio(), 0.0);
    }

    #[test]
    fn mode_strings() {
        assert_eq!(ScanPrecision::Exact.mode(), "exact");
        assert_eq!(ScanPrecision::Sq8 { rerank: 1 }.mode(), "sq8");
        assert_eq!(ScanPrecision::Pq { m: 2, bits: 4, rerank: 1 }.mode(), "pq");
        assert_eq!(
            ScanPrecision::Sq8 { rerank: 0 }.with_rerank(7),
            ScanPrecision::Sq8 { rerank: 7 }
        );
    }
}
