//! Per-request spans: stage-by-stage timing records emitted as JSON
//! lines.
//!
//! A [`TraceRecord`] is one serving tier's view of one request — the
//! router and every shard it contacted each emit their own record
//! carrying the *same* trace id, and [`stitch`] groups a log back into
//! per-request trees.  Records are flat (a span is a named duration,
//! not a subtree): the tree structure lives in the shared id plus the
//! `role` field, which is all the stage-attribution questions we ask
//! ("where did this slow request spend its time?") need.
//!
//! Sampling is decided once, at admission: [`TraceSink::sample_id`]
//! returns a fresh non-zero id for every `sample_every`-th request (0
//! otherwise), and a slow-query threshold lets the serving tier
//! force-emit an outlier after the fact via [`TraceSink::force_id`].
//! A request with trace id 0 allocates nothing and touches no lock.

use std::collections::BTreeMap;
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::clock::monotonic_ns;
use crate::util::sync::lock_unpoisoned;
use crate::util::Json;

/// One tier's timing record for one traced request.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    /// Request-tree id shared by every tier's record (never 0).
    pub trace_id: u64,
    /// Emitting tier: `"search"` for a coordinator, `"router"` for the
    /// cluster scatter-gather tier.
    pub role: String,
    /// The tier-local request id (wire frame id on the shard side).
    pub req_id: u64,
    /// End-to-end time at this tier, admission to response write (ns).
    pub total_ns: u64,
    /// Ordered `(stage, duration_ns)` spans.  Stage sets per role are
    /// documented in the README's span table.
    pub spans: Vec<(String, u64)>,
}

impl TraceRecord {
    /// Sum of all span durations — by construction at most
    /// [`Self::total_ns`] (stages partition or under-cover the request;
    /// batch-shared stages are attributed per request as an equal
    /// share).
    pub fn spans_total_ns(&self) -> u64 {
        self.spans.iter().map(|(_, ns)| ns).sum()
    }

    /// Duration of the named span, if recorded.
    pub fn span_ns(&self, name: &str) -> Option<u64> {
        self.spans
            .iter()
            .find(|(n, _)| n == name)
            .map(|&(_, ns)| ns)
    }

    /// The record as one JSON object (what [`TraceSink::emit`] writes,
    /// one per line).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("trace_id".to_string(), Json::Num(self.trace_id as f64));
        o.insert("role".to_string(), Json::Str(self.role.clone()));
        o.insert("req_id".to_string(), Json::Num(self.req_id as f64));
        o.insert("total_ns".to_string(), Json::Num(self.total_ns as f64));
        let spans = self
            .spans
            .iter()
            .map(|(name, ns)| {
                let mut s = BTreeMap::new();
                s.insert("stage".to_string(), Json::Str(name.clone()));
                s.insert("ns".to_string(), Json::Num(*ns as f64));
                Json::Obj(s)
            })
            .collect();
        o.insert("spans".to_string(), Json::Arr(spans));
        Json::Obj(o)
    }

    /// Parse a record back from its JSON form (test/tooling side of the
    /// emit path).
    pub fn from_json(j: &Json) -> Option<TraceRecord> {
        let spans = j
            .get("spans")?
            .as_arr()?
            .iter()
            .map(|s| {
                Some((
                    s.get("stage")?.as_str()?.to_string(),
                    s.get("ns")?.as_u64()?,
                ))
            })
            .collect::<Option<Vec<_>>>()?;
        Some(TraceRecord {
            trace_id: j.get("trace_id")?.as_u64()?,
            role: j.get("role")?.as_str()?.to_string(),
            req_id: j.get("req_id")?.as_u64()?,
            total_ns: j.get("total_ns")?.as_u64()?,
            spans,
        })
    }
}

/// Group records by trace id — reassembles the per-request tree a
/// router-side record and its shard-side records form.  Record order
/// within a group follows the input (emission) order.
pub fn stitch(records: &[TraceRecord]) -> BTreeMap<u64, Vec<&TraceRecord>> {
    let mut out: BTreeMap<u64, Vec<&TraceRecord>> = BTreeMap::new();
    for r in records {
        out.entry(r.trace_id).or_default().push(r);
    }
    out
}

/// In-progress record builder for one traced request at one tier.
/// Total time runs from [`Trace::start`] unless the caller supplies its
/// own measurement via [`Trace::finish_with_total`] (the coordinator
/// does: its clock starts at enqueue, before the worker sees the
/// request).
#[derive(Debug)]
pub struct Trace {
    rec: TraceRecord,
    started_ns: u64,
}

impl Trace {
    /// Begin a trace at the current process clock.
    pub fn start(trace_id: u64, role: &str, req_id: u64) -> Trace {
        Trace {
            rec: TraceRecord {
                trace_id,
                role: role.to_string(),
                req_id,
                total_ns: 0,
                spans: Vec::new(),
            },
            started_ns: monotonic_ns(),
        }
    }

    /// Append a pre-measured span.
    pub fn span_ns(&mut self, stage: &str, ns: u64) {
        self.rec.spans.push((stage.to_string(), ns));
    }

    /// Finish with `total_ns` measured by the caller.
    pub fn finish_with_total(mut self, total_ns: u64) -> TraceRecord {
        self.rec.total_ns = total_ns;
        self.rec
    }

    /// Finish, measuring total time from [`Trace::start`].
    pub fn finish(self) -> TraceRecord {
        let total = monotonic_ns().saturating_sub(self.started_ns);
        self.finish_with_total(total)
    }
}

/// Shared JSON-lines trace destination with sampling policy.
///
/// * `sample_every = 0` never samples (only slow-query force-sampling
///   can still emit); `sample_every = n` samples every n-th admission.
/// * `slow_ns = 0` disables the slow-query threshold; otherwise a tier
///   that observes `total_ns >= slow_ns` on an unsampled request calls
///   [`TraceSink::force_id`] and emits the outlier.
///
/// Ids are allocated from one process-wide counter starting at 1, so 0
/// unambiguously means "untraced" everywhere (wire field included).
/// Write errors are swallowed: observability must never fail serving.
///
/// Records are buffered in the underlying writer ([`TraceSink::to_file`]
/// wraps the file in a `BufWriter`); graceful shutdown calls
/// [`TraceSink::flush`] so the tail of the log is on disk before the
/// process exits or a test inspects the file.
pub struct TraceSink {
    out: Mutex<Box<dyn Write + Send>>,
    sample_every: u64,
    slow_ns: u64,
    admissions: AtomicU64,
    next_id: AtomicU64,
    emitted: AtomicU64,
}

impl std::fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TraceSink")
            .field("sample_every", &self.sample_every)
            .field("slow_ns", &self.slow_ns)
            .field("emitted", &self.emitted.load(Ordering::Relaxed))
            .finish()
    }
}

impl TraceSink {
    /// Sink writing to `out` with the given sampling policy.
    pub fn new(
        out: Box<dyn Write + Send>,
        sample_every: u64,
        slow_ns: u64,
    ) -> Arc<TraceSink> {
        Arc::new(TraceSink {
            out: Mutex::new(out),
            sample_every,
            slow_ns,
            admissions: AtomicU64::new(0),
            next_id: AtomicU64::new(1),
            emitted: AtomicU64::new(0),
        })
    }

    /// Sink appending JSON lines to `path` (created if absent).
    pub fn to_file(
        path: &std::path::Path,
        sample_every: u64,
        slow_ns: u64,
    ) -> crate::error::Result<Arc<TraceSink>> {
        let f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .map_err(|e| {
                crate::error::Error::Config(format!(
                    "trace sink {}: {e}",
                    path.display()
                ))
            })?;
        let buffered = std::io::BufWriter::new(f);
        Ok(Self::new(Box::new(buffered), sample_every, slow_ns))
    }

    /// Admission-time sampling decision: a fresh trace id for every
    /// `sample_every`-th call, 0 otherwise.  Lock-free.
    pub fn sample_id(&self) -> u64 {
        if self.sample_every == 0 {
            return 0;
        }
        let n = self.admissions.fetch_add(1, Ordering::Relaxed);
        if n % self.sample_every == 0 {
            self.alloc_id()
        } else {
            0
        }
    }

    /// Unconditionally allocate a trace id (slow-query force-sampling).
    pub fn force_id(&self) -> u64 {
        self.alloc_id()
    }

    fn alloc_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Slow-query threshold in ns (0 = disabled).
    pub fn slow_ns(&self) -> u64 {
        self.slow_ns
    }

    /// Records emitted so far (tests and the serve loop's exit summary).
    pub fn emitted(&self) -> u64 {
        self.emitted.load(Ordering::Relaxed)
    }

    /// Write one record as a JSON line.  IO errors are ignored.  The
    /// line stays in the writer's buffer until it fills or
    /// [`Self::flush`] runs — per-record fsync-ish flushing measurably
    /// taxed the trace path for no durability the reader could rely on
    /// mid-run anyway.
    pub fn emit(&self, rec: &TraceRecord) {
        let line = rec.to_json().to_string();
        let mut out = lock_unpoisoned(&self.out);
        let _ = writeln!(out, "{line}");
        self.emitted.fetch_add(1, Ordering::Relaxed);
    }

    /// Flush buffered records through the underlying writer.  Called by
    /// graceful shutdown (`SearchServer::shutdown`,
    /// `ClusterRouter::shutdown`) so no emitted record is lost in the
    /// buffer when the process drains; IO errors are ignored like
    /// [`Self::emit`]'s.
    pub fn flush(&self) {
        let mut out = lock_unpoisoned(&self.out);
        // amlint: allow(store_io, reason = "trace output is diagnostic; a full disk must not fail shutdown")
        let _ = out.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_roundtrips_through_json() {
        let rec = TraceRecord {
            trace_id: 7,
            role: "router".to_string(),
            req_id: 42,
            total_ns: 1000,
            spans: vec![("queue".to_string(), 100), ("scatter".to_string(), 300)],
        };
        let parsed = TraceRecord::from_json(&rec.to_json()).unwrap();
        assert_eq!(parsed, rec);
        assert_eq!(parsed.spans_total_ns(), 400);
        assert_eq!(parsed.span_ns("scatter"), Some(300));
        assert_eq!(parsed.span_ns("missing"), None);
    }

    #[test]
    fn trace_builder_orders_spans_and_bounds_total() {
        let mut t = Trace::start(9, "search", 1);
        t.span_ns("queue", 10);
        t.span_ns("score", 20);
        let rec = t.finish_with_total(100);
        assert_eq!(rec.spans, vec![("queue".into(), 10), ("score".into(), 20)]);
        assert!(rec.spans_total_ns() <= rec.total_ns);
        // self-timed variant: total covers the builder's lifetime
        let t = Trace::start(10, "search", 2);
        std::thread::sleep(std::time::Duration::from_millis(1));
        let rec = t.finish();
        assert!(rec.total_ns > 0);
    }

    #[test]
    fn stitching_groups_tiers_under_one_id() {
        let router = TraceRecord {
            trace_id: 5,
            role: "router".into(),
            req_id: 1,
            total_ns: 900,
            spans: vec![("gather".into(), 500)],
        };
        let shard = TraceRecord {
            trace_id: 5,
            role: "search".into(),
            req_id: 11,
            total_ns: 400,
            spans: vec![("scan".into(), 300)],
        };
        let other = TraceRecord { trace_id: 6, ..shard.clone() };
        let recs = vec![router.clone(), shard.clone(), other];
        let trees = stitch(&recs);
        assert_eq!(trees.len(), 2);
        let tree = &trees[&5];
        assert_eq!(tree.len(), 2);
        assert_eq!(tree[0].role, "router");
        assert_eq!(tree[1].role, "search");
    }

    #[test]
    fn sampling_rate_and_force() {
        let sink = TraceSink::new(Box::new(std::io::sink()), 3, 0);
        let ids: Vec<u64> = (0..9).map(|_| sink.sample_id()).collect();
        let sampled: Vec<u64> = ids.iter().copied().filter(|&i| i != 0).collect();
        assert_eq!(sampled.len(), 3, "every 3rd admission samples: {ids:?}");
        assert!(sampled.windows(2).all(|w| w[0] < w[1]), "ids increase");
        assert!(sink.force_id() > 0);
        // disabled sink never samples but can still force
        let off = TraceSink::new(Box::new(std::io::sink()), 0, 1_000);
        assert!((0..100).all(|_| off.sample_id() == 0));
        assert_eq!(off.slow_ns(), 1_000);
        assert!(off.force_id() > 0);
    }

    #[test]
    fn emit_writes_one_parseable_line_per_record() {
        use std::sync::{Arc, Mutex};
        // a Write impl capturing into shared memory
        #[derive(Clone)]
        struct Buf(Arc<Mutex<Vec<u8>>>);
        impl Write for Buf {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let buf = Buf(Arc::new(Mutex::new(Vec::new())));
        let sink = TraceSink::new(Box::new(buf.clone()), 1, 0);
        let rec = TraceRecord {
            trace_id: 1,
            role: "search".into(),
            req_id: 2,
            total_ns: 3,
            spans: vec![("scan".into(), 2)],
        };
        sink.emit(&rec);
        sink.emit(&rec);
        assert_eq!(sink.emitted(), 2);
        let bytes = buf.0.lock().unwrap().clone();
        let text = String::from_utf8(bytes).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let j = Json::parse(line).unwrap();
            assert_eq!(TraceRecord::from_json(&j).unwrap(), rec);
        }
    }

    #[test]
    fn flush_pushes_buffered_records_through() {
        use std::sync::{Arc, Mutex};
        #[derive(Clone)]
        struct Buf(Arc<Mutex<Vec<u8>>>);
        impl Write for Buf {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let buf = Buf(Arc::new(Mutex::new(Vec::new())));
        // same buffering as `to_file`: records sit in the BufWriter
        // until `flush` — the shutdown path must drain them
        let sink = TraceSink::new(
            Box::new(std::io::BufWriter::new(buf.clone())),
            1,
            0,
        );
        let rec = TraceRecord {
            trace_id: 1,
            role: "search".into(),
            req_id: 2,
            total_ns: 3,
            spans: vec![("scan".into(), 2)],
        };
        sink.emit(&rec);
        assert_eq!(sink.emitted(), 1);
        assert!(
            buf.0.lock().unwrap().is_empty(),
            "short record stays buffered until flush"
        );
        sink.flush();
        let bytes = buf.0.lock().unwrap().clone();
        let text = String::from_utf8(bytes).unwrap();
        assert_eq!(text.lines().count(), 1);
        let j = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(TraceRecord::from_json(&j).unwrap(), rec);
    }
}
