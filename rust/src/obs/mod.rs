//! Observability: per-request tracing and a unified metrics export
//! surface for the serving stack.
//!
//! Two halves, both zero-dependency:
//!
//! * [`trace`] — per-request spans.  Each serving tier stamps stage
//!   durations (queue wait, batch formation, score, select, scan,
//!   response write; scatter/gather in the router) onto a
//!   [`trace::TraceRecord`] and emits it as one JSON line through a
//!   shared [`trace::TraceSink`].  The trace id travels to shards
//!   inside the SEARCH frame (wire v2), so a router-side trace and its
//!   shard-side spans stitch into one tree by id.  The untraced path
//!   allocates nothing: a request whose trace id is 0 never builds a
//!   record.
//! * [`prom`] — a [`prom::Registry`] of counters, gauges, and
//!   histogram summaries rendered in Prometheus text exposition
//!   format.  `SearchServer`, `ClusterRouter`, and `NetServer` all
//!   feed the same registry from the same one-lock metrics snapshot
//!   that backs the STATS JSON, so the two export surfaces cannot
//!   disagree.
//! * [`quality`] — the accuracy axis: online recall estimation from
//!   shadow-executed exact answers, poll-selectivity histograms, and
//!   candidate-survival funnels, exported through the same snapshot.

pub mod prom;
pub mod quality;
pub mod trace;

pub use prom::{Registry, REQUIRED_FAMILIES};
pub use quality::{sample_hit, QualityStats, RankHistogram, ShadowQueue, SurvivalStats};
pub use trace::{stitch, Trace, TraceRecord, TraceSink};
