//! Prometheus text-exposition rendering from a single metrics registry.
//!
//! Every serving tier converts its one-lock metrics snapshot into a
//! [`Registry`] (counters, gauges, and histogram summaries), the net
//! front door appends its own transport gauges and a `role` label, and
//! [`Registry::render`] produces the `METRICS` frame payload.  Because
//! the registry and the STATS JSON are both derived from the same
//! snapshot, the two export surfaces cannot disagree.
//!
//! Metric families are pinned by name below (`M_*`).  amlint's drift
//! rule holds these constants, the README metric table, and the
//! exposition output together — renaming a family without updating the
//! docs is a lint failure, like renumbering an `ERR_*` code.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::metrics::LatencyHistogram;

/// Requests accepted (counter).
pub const M_REQUESTS: &str = "amsearch_requests_total";
/// Requests that failed (counter; router tier).
pub const M_ERRORS: &str = "amsearch_errors_total";
/// Batches executed (counter; coordinator tier).
pub const M_BATCHES: &str = "amsearch_batches_total";
/// Elementary operations by `stage` label (counter; coordinator tier).
pub const M_OPS: &str = "amsearch_ops_total";
/// End-to-end request latency since boot (summary).
pub const M_LATENCY: &str = "amsearch_latency_ns";
/// In-engine service time since boot (summary; coordinator tier).
pub const M_SERVICE: &str = "amsearch_service_ns";
/// End-to-end request latency over the rolling window (summary).
pub const M_WINDOW_LATENCY: &str = "amsearch_window_latency_ns";
/// Per-shard service time since boot, `shard` label (summary; router).
pub const M_SHARD_SERVICE: &str = "amsearch_shard_service_ns";
/// Per-shard service time over the rolling window, `shard` label
/// (summary; router).
pub const M_SHARD_WINDOW: &str = "amsearch_shard_window_service_ns";
/// Connections refused with `ERR_OVERLOADED` (counter; net layer).
pub const M_NET_REFUSED: &str = "amsearch_net_refused_connections_total";
/// Searches currently pipelined across all connections (gauge; net
/// layer).
pub const M_NET_INFLIGHT: &str = "amsearch_net_inflight";
/// Shadow comparisons folded into the online recall estimate (counter;
/// exported whenever `--quality-sample` is configured).
pub const M_QUALITY_SAMPLES: &str = "amsearch_quality_samples_total";
/// Sampled requests dropped by the bounded shadow queue (counter).
pub const M_QUALITY_DROPPED: &str = "amsearch_quality_dropped_total";
/// Online micro-averaged recall@k estimate (gauge in [0, 1]).
pub const M_QUALITY_RECALL: &str = "amsearch_quality_recall";
/// Mean rank displacement of served neighbors vs exact (gauge).
pub const M_QUALITY_RANK_DISPLACEMENT: &str = "amsearch_quality_rank_displacement";
/// Mean relative distance error of served neighbors vs exact (gauge).
pub const M_QUALITY_DISTANCE_ERROR: &str = "amsearch_quality_distance_error";
/// Fraction of answers won by the top-ranked polled class / contacted
/// shard (gauge; 1.0 = the fan-out tail never decided an answer).
pub const M_QUALITY_TOP1_FRACTION: &str = "amsearch_quality_top1_fraction";
/// Candidate-survival ratio through the scan/rerank funnel (gauge).
pub const M_QUALITY_SURVIVAL: &str = "amsearch_quality_survival_ratio";
/// Per-shard capture rate of the full-fanout truth set, `shard` label
/// (gauge in [0, 1]; router, sampled).
pub const M_QUALITY_SHARD_CAPTURE: &str = "amsearch_quality_shard_capture_rate";
/// Bytes read from the paged vector store's `.amdat` extent file
/// (counter; zero on a resident store).
pub const M_STORE_BYTES_READ: &str = "amsearch_store_bytes_read_total";
/// Class extents fetched from disk by the paged store (counter).
pub const M_STORE_EXTENT_READS: &str = "amsearch_store_extent_reads_total";
/// Class-extent lookups answered by the paged store's LRU cache
/// (counter).
pub const M_STORE_CACHE_HITS: &str = "amsearch_store_cache_hits_total";
/// Class-extent lookups that had to fetch from disk (counter).
pub const M_STORE_CACHE_MISSES: &str = "amsearch_store_cache_misses_total";
/// Extents evicted from the paged store's LRU cache (counter).
pub const M_STORE_CACHE_EVICTIONS: &str = "amsearch_store_cache_evictions_total";
/// Bytes of exact member vectors currently memory-resident: the full
/// slab size on a resident store, the cached-extent bytes on a paged
/// one (gauge).
pub const M_STORE_RESIDENT_BYTES: &str = "amsearch_store_resident_bytes";

/// Families every tier's exposition must contain — what the CLI's
/// `metrics --check` and the CI smoke scrape assert.
pub const REQUIRED_FAMILIES: [&str; 3] = [M_REQUESTS, M_LATENCY, M_WINDOW_LATENCY];

/// Store I/O families, additionally asserted by `metrics --check
/// --require-store` and the paged CI smoke (the single-node search tier
/// always exports them; the router tier does not, so they are not in
/// [`REQUIRED_FAMILIES`]).
pub const STORE_FAMILIES: [&str; 6] = [
    M_STORE_BYTES_READ,
    M_STORE_EXTENT_READS,
    M_STORE_CACHE_HITS,
    M_STORE_CACHE_MISSES,
    M_STORE_CACHE_EVICTIONS,
    M_STORE_RESIDENT_BYTES,
];

/// The quantiles a histogram family exports (matches the STATS JSON's
/// `p50_ns`/`p90_ns`/`p99_ns`, plus `quantile="1"` for the exact max).
const QUANTILES: [(f64, &str); 4] =
    [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99"), (1.0, "1")];

#[derive(Debug, Clone)]
struct Sample {
    /// Family-name suffix (`""`, `"_sum"`, `"_count"`).
    suffix: &'static str,
    labels: Vec<(String, String)>,
    value: f64,
}

#[derive(Debug, Clone)]
struct Family {
    /// `counter` | `gauge` | `summary` — the kind first registered for
    /// the name wins.
    kind: &'static str,
    samples: Vec<Sample>,
}

/// An ordered collection of metric families, rendered as Prometheus
/// text exposition.
#[derive(Debug, Clone, Default)]
pub struct Registry {
    families: BTreeMap<String, Family>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Registry {
        Registry::default()
    }

    fn push(
        &mut self,
        name: &str,
        kind: &'static str,
        suffix: &'static str,
        labels: &[(&str, &str)],
        value: f64,
    ) {
        let fam = self
            .families
            .entry(name.to_string())
            .or_insert_with(|| Family { kind, samples: Vec::new() });
        fam.samples.push(Sample {
            suffix,
            labels: labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
            value,
        });
    }

    /// Add a counter sample.
    pub fn counter(&mut self, name: &str, labels: &[(&str, &str)], value: u64) {
        self.push(name, "counter", "", labels, value as f64);
    }

    /// Add a gauge sample.
    pub fn gauge(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.push(name, "gauge", "", labels, value);
    }

    /// Add a latency histogram as a Prometheus summary: one sample per
    /// quantile in [`QUANTILES`] plus `_sum` and `_count`.
    pub fn histogram(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        h: &LatencyHistogram,
    ) {
        for (q, qlabel) in QUANTILES {
            let mut ql: Vec<(&str, &str)> = labels.to_vec();
            ql.push(("quantile", qlabel));
            let v = if q >= 1.0 { h.max_ns() } else { h.quantile_ns(q) };
            self.push(name, "summary", "", &ql, v as f64);
        }
        self.push(name, "summary", "_sum", labels, h.sum_ns());
        self.push(name, "summary", "_count", labels, h.count() as f64);
    }

    /// Set label `key` to `value` on every sample, replacing any
    /// existing value — how the net front door stamps its `role` onto a
    /// backend-built registry.
    pub fn relabel(&mut self, key: &str, value: &str) {
        for fam in self.families.values_mut() {
            for s in &mut fam.samples {
                match s.labels.iter_mut().find(|(k, _)| k == key) {
                    Some(pair) => pair.1 = value.to_string(),
                    None => s.labels.push((key.to_string(), value.to_string())),
                }
            }
        }
    }

    /// Render the text exposition: a `# TYPE` line per family followed
    /// by its samples, families in name order.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, fam) in &self.families {
            let _ = writeln!(out, "# TYPE {name} {}", fam.kind);
            for s in &fam.samples {
                out.push_str(name);
                out.push_str(s.suffix);
                if !s.labels.is_empty() {
                    out.push('{');
                    for (i, (k, v)) in s.labels.iter().enumerate() {
                        if i > 0 {
                            out.push(',');
                        }
                        let _ = write!(out, "{k}=\"{}\"", escape_label(v));
                    }
                    out.push('}');
                }
                out.push(' ');
                write_value(&mut out, s.value);
                out.push('\n');
            }
        }
        out
    }
}

/// Prometheus label-value escaping: backslash, quote, newline.
fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Integral values render without a decimal point (same convention as
/// `util::json`).
fn write_value(out: &mut String, v: f64) {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{v}");
    }
}

fn valid_metric_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Parse one sample line, returning its family-or-sample name.
fn check_sample_line(line: &str) -> Result<(), String> {
    let name_end = line
        .find(|c: char| c == '{' || c.is_ascii_whitespace())
        .unwrap_or(line.len());
    let name = &line[..name_end];
    if !valid_metric_name(name) {
        return Err(format!("bad metric name {name:?}"));
    }
    let rest = &line[name_end..];
    let rest = if let Some(after) = rest.strip_prefix('{') {
        // scan the label block, honouring escapes inside quoted values
        let bytes = after.as_bytes();
        let mut i = 0usize;
        let mut in_quotes = false;
        let mut closed = None;
        while i < bytes.len() {
            match bytes[i] {
                b'\\' if in_quotes => i += 1, // skip the escaped byte
                b'"' => in_quotes = !in_quotes,
                b'}' if !in_quotes => {
                    closed = Some(i);
                    break;
                }
                _ => {}
            }
            i += 1;
        }
        let Some(end) = closed else {
            return Err("unterminated label block".to_string());
        };
        let inner = &after[..end];
        if !inner.is_empty() {
            // every label must look like key="value"
            for part in split_labels(inner) {
                let Some((k, v)) = part.split_once('=') else {
                    return Err(format!("label without '=': {part:?}"));
                };
                if !valid_metric_name(k) {
                    return Err(format!("bad label name {k:?}"));
                }
                if !(v.starts_with('"') && v.ends_with('"') && v.len() >= 2) {
                    return Err(format!("unquoted label value {v:?}"));
                }
            }
        }
        &after[end + 1..]
    } else {
        rest
    };
    let value = rest.trim();
    if value.is_empty() {
        return Err("sample line has no value".to_string());
    }
    // value may carry an optional timestamp; the first token must parse
    let first = value.split_ascii_whitespace().next().unwrap_or("");
    if first.parse::<f64>().is_err()
        && !matches!(first, "NaN" | "+Inf" | "-Inf")
    {
        return Err(format!("unparseable sample value {first:?}"));
    }
    Ok(())
}

/// Split a label block on commas that sit outside quoted values.
fn split_labels(inner: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let bytes = inner.as_bytes();
    let mut start = 0usize;
    let mut in_quotes = false;
    let mut i = 0usize;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' if in_quotes => i += 1,
            b'"' => in_quotes = !in_quotes,
            b',' if !in_quotes => {
                out.push(inner[start..i].trim());
                start = i + 1;
            }
            _ => {}
        }
        i += 1;
    }
    if start < inner.len() {
        out.push(inner[start..].trim());
    }
    out
}

/// Validate a text exposition: every line must be a well-formed comment
/// or sample, and every family in `required` must be declared by a
/// `# TYPE` line.  Returns the first problem found — the CLI's
/// `metrics --check` and the CI smoke scrape both call this.
pub fn validate(text: &str, required: &[&str]) -> Result<(), String> {
    let mut declared: Vec<&str> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split_ascii_whitespace();
            let (name, kind) = (parts.next(), parts.next());
            match (name, kind) {
                (Some(n), Some(k))
                    if valid_metric_name(n)
                        && matches!(
                            k,
                            "counter" | "gauge" | "summary" | "histogram" | "untyped"
                        )
                        && parts.next().is_none() =>
                {
                    declared.push(n);
                }
                _ => {
                    return Err(format!("line {}: malformed # TYPE: {line:?}", lineno + 1))
                }
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // HELP or free-form comment
        }
        check_sample_line(line)
            .map_err(|e| format!("line {}: {e}: {line:?}", lineno + 1))?;
    }
    for req in required {
        if !declared.contains(req) {
            return Err(format!("missing required metric family {req}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_names_are_prefixed_and_unique() {
        let all = [
            M_REQUESTS,
            M_ERRORS,
            M_BATCHES,
            M_OPS,
            M_LATENCY,
            M_SERVICE,
            M_WINDOW_LATENCY,
            M_SHARD_SERVICE,
            M_SHARD_WINDOW,
            M_NET_REFUSED,
            M_NET_INFLIGHT,
            M_QUALITY_SAMPLES,
            M_QUALITY_DROPPED,
            M_QUALITY_RECALL,
            M_QUALITY_RANK_DISPLACEMENT,
            M_QUALITY_DISTANCE_ERROR,
            M_QUALITY_TOP1_FRACTION,
            M_QUALITY_SURVIVAL,
            M_QUALITY_SHARD_CAPTURE,
            M_STORE_BYTES_READ,
            M_STORE_EXTENT_READS,
            M_STORE_CACHE_HITS,
            M_STORE_CACHE_MISSES,
            M_STORE_CACHE_EVICTIONS,
            M_STORE_RESIDENT_BYTES,
        ];
        let unique: std::collections::BTreeSet<&str> = all.iter().copied().collect();
        assert_eq!(unique.len(), all.len());
        for name in all {
            assert!(name.starts_with("amsearch_"), "{name}");
            assert!(valid_metric_name(name), "{name}");
        }
        for req in REQUIRED_FAMILIES {
            assert!(all.contains(&req));
        }
    }

    #[test]
    fn quality_family_names_are_pinned() {
        // operators alert on these names; renaming one is a breaking
        // change that must show up here (and in README) on purpose
        assert_eq!(M_QUALITY_SAMPLES, "amsearch_quality_samples_total");
        assert_eq!(M_QUALITY_DROPPED, "amsearch_quality_dropped_total");
        assert_eq!(M_QUALITY_RECALL, "amsearch_quality_recall");
        assert_eq!(M_QUALITY_RANK_DISPLACEMENT, "amsearch_quality_rank_displacement");
        assert_eq!(M_QUALITY_DISTANCE_ERROR, "amsearch_quality_distance_error");
        assert_eq!(M_QUALITY_TOP1_FRACTION, "amsearch_quality_top1_fraction");
        assert_eq!(M_QUALITY_SURVIVAL, "amsearch_quality_survival_ratio");
        assert_eq!(
            M_QUALITY_SHARD_CAPTURE,
            "amsearch_quality_shard_capture_rate"
        );
    }

    #[test]
    fn store_family_names_are_pinned() {
        assert_eq!(M_STORE_BYTES_READ, "amsearch_store_bytes_read_total");
        assert_eq!(M_STORE_EXTENT_READS, "amsearch_store_extent_reads_total");
        assert_eq!(M_STORE_CACHE_HITS, "amsearch_store_cache_hits_total");
        assert_eq!(M_STORE_CACHE_MISSES, "amsearch_store_cache_misses_total");
        assert_eq!(
            M_STORE_CACHE_EVICTIONS,
            "amsearch_store_cache_evictions_total"
        );
        assert_eq!(M_STORE_RESIDENT_BYTES, "amsearch_store_resident_bytes");
        for f in STORE_FAMILIES {
            assert!(f.starts_with("amsearch_store_"), "{f}");
        }
    }

    #[test]
    fn render_groups_families_and_validates() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100u64 {
            h.record_ns(i * 1_000);
        }
        let mut reg = Registry::new();
        reg.counter(M_REQUESTS, &[], 42);
        reg.gauge(M_NET_INFLIGHT, &[], 3.0);
        reg.histogram(M_LATENCY, &[], &h);
        reg.histogram(M_WINDOW_LATENCY, &[("shard", "0")], &h);
        reg.relabel("role", "search");
        let text = reg.render();
        assert!(text.contains("# TYPE amsearch_requests_total counter"));
        assert!(text.contains("amsearch_requests_total{role=\"search\"} 42"));
        assert!(text.contains("# TYPE amsearch_latency_ns summary"));
        assert!(text.contains("quantile=\"0.99\""));
        assert!(text.contains("amsearch_latency_ns_count{role=\"search\"} 100"));
        assert!(text
            .contains("amsearch_window_latency_ns_sum{shard=\"0\",role=\"search\"}"));
        // exactly one TYPE line per family
        let type_lines =
            text.lines().filter(|l| l.starts_with("# TYPE amsearch_latency_ns ")).count();
        assert_eq!(type_lines, 1);
        validate(&text, &REQUIRED_FAMILIES).unwrap();
    }

    #[test]
    fn relabel_overrides_existing_value() {
        let mut reg = Registry::new();
        reg.counter(M_REQUESTS, &[("role", "old")], 1);
        reg.relabel("role", "shard");
        assert!(reg.render().contains("role=\"shard\""));
        assert!(!reg.render().contains("old"));
    }

    #[test]
    fn validate_rejects_malformed_lines() {
        assert!(validate("# TYPE amsearch_x counter\namsearch_x 1\n", &[]).is_ok());
        let missing = validate("# TYPE amsearch_x counter\namsearch_x 1\n",
            &["amsearch_requests_total"]);
        assert!(missing.unwrap_err().contains("missing required"));
        assert!(validate("2bad_name 1\n", &[]).is_err());
        assert!(validate("amsearch_x{unclosed=\"v\" 1\n", &[]).is_err());
        assert!(validate("amsearch_x{k=unquoted} 1\n", &[]).is_err());
        assert!(validate("amsearch_x notanumber\n", &[]).is_err());
        assert!(validate("amsearch_x\n", &[]).is_err());
        assert!(validate("# TYPE amsearch_x nonsense\n", &[]).is_err());
        // escapes inside label values are fine
        validate("amsearch_x{msg=\"a\\\"b,c\"} 1\n", &[]).unwrap();
        validate("amsearch_x NaN\namsearch_y +Inf\n", &[]).unwrap();
    }

    #[test]
    fn label_escaping_roundtrips_through_validation() {
        let mut reg = Registry::new();
        reg.counter(M_REQUESTS, &[("path", "a\"b\\c\nd")], 1);
        validate(&reg.render(), &[]).unwrap();
    }
}
