//! Quality observability: online recall estimation and poll-selectivity
//! telemetry.
//!
//! The paper's serving stack trades accuracy for work (poll `p < q`
//! classes, contact `s < N` shards); PR 7 made the *latency* side of
//! that trade observable, this module makes the *accuracy* side
//! observable:
//!
//! * [`QualityStats`] — rolling recall@k / rank-displacement /
//!   distance-error estimates, fed by shadow-executed exact answers for
//!   every `quality_sample`-th request.
//! * [`RankHistogram`] — "where did the winner come from": the rank of
//!   the polled class (coordinator) or contacted shard (router) that
//!   produced the final top-1 — the fan-out-effectiveness signal that
//!   says whether the last ranks of the poll ever matter.
//! * [`SurvivalStats`] — candidate-survival through the scan: how many
//!   scanned candidates survive into the returned top-k (the SQ8/PQ
//!   rerank funnel).
//! * [`ShadowQueue`] — the bounded drop-oldest handoff between the hot
//!   serving path and the low-priority shadow worker; under load the
//!   estimate loses samples, never the serving path.
//!
//! All counters live under each tier's existing one-lock metrics
//! snapshot; nothing here takes extra locks on the hot path.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::search::Neighbor;
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned};
use crate::util::Json;

/// Rolling quality estimate built from (served, exact) answer pairs.
///
/// `recall` is micro-averaged (total overlap over total truth size), so
/// requests with larger `k` weigh proportionally — the same convention
/// as the offline [`crate::metrics::RecallAtK`] evaluator it is checked
/// against in e2e.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QualityStats {
    /// Shadow comparisons completed.
    pub samples: u64,
    /// Sampled requests the bounded queue had to drop under load.
    pub dropped: u64,
    /// Σ |served ∩ exact| over samples.
    pub hit_sum: u64,
    /// Σ |exact| over samples (the denominator of micro recall@k).
    pub truth_sum: u64,
    /// Samples whose served answer matched the exact answer id-for-id.
    pub exact_matches: u64,
    /// Σ rank displacement: for the served neighbor at rank `i`, its
    /// rank in the exact list minus `i`; a served id absent from the
    /// exact top-k is charged the cap `|exact|`.
    pub displacement_sum: u64,
    /// Served positions inspected for displacement.
    pub displacement_count: u64,
    /// Σ relative distance error of the served rank-`i` distance vs the
    /// exact rank-`i` distance (0 when the served answer is exact).
    pub distance_err_sum: f64,
    /// Rank pairs inspected for distance error.
    pub distance_err_count: u64,
}

impl QualityStats {
    /// Fold one (served, exact) comparison into the estimate.  `exact`
    /// must be the ground-truth top-k for the same query, sorted
    /// ascending by `(distance, id)` like every neighbor list.
    pub fn record_comparison(&mut self, served: &[Neighbor], exact: &[Neighbor]) {
        self.samples += 1;
        self.truth_sum += exact.len() as u64;
        let mut hits = 0u64;
        for (i, s) in served.iter().enumerate() {
            // exact lists are k-bounded (k <= 65536 on the wire), so a
            // linear membership probe beats building a set per sample
            match exact.iter().position(|e| e.id == s.id) {
                Some(j) => {
                    hits += 1;
                    self.displacement_sum += (j as i64 - i as i64).unsigned_abs();
                }
                None => self.displacement_sum += exact.len() as u64,
            }
            self.displacement_count += 1;
        }
        self.hit_sum += hits;
        let ids_match = served.len() == exact.len()
            && served.iter().zip(exact).all(|(s, e)| s.id == e.id);
        if ids_match {
            self.exact_matches += 1;
        }
        for (s, e) in served.iter().zip(exact) {
            let denom = e.distance.abs().max(1e-12) as f64;
            let err = (s.distance as f64 - e.distance as f64) / denom;
            // the exact distance at a rank is optimal, so the served
            // distance can only be >=; clamp fp noise at zero
            self.distance_err_sum += err.max(0.0);
            self.distance_err_count += 1;
        }
    }

    /// Micro-averaged recall@k over all samples (1.0 before any sample
    /// arrives, so an untouched gauge reads "no evidence of loss").
    pub fn recall(&self) -> f64 {
        if self.truth_sum == 0 {
            1.0
        } else {
            self.hit_sum as f64 / self.truth_sum as f64
        }
    }

    /// Mean rank displacement per served position.
    pub fn mean_displacement(&self) -> f64 {
        if self.displacement_count == 0 {
            0.0
        } else {
            self.displacement_sum as f64 / self.displacement_count as f64
        }
    }

    /// Mean relative distance error per compared rank.
    pub fn mean_distance_error(&self) -> f64 {
        if self.distance_err_count == 0 {
            0.0
        } else {
            self.distance_err_sum / self.distance_err_count as f64
        }
    }

    /// Fold another estimate in (per-shard → cluster aggregation).
    pub fn merge(&mut self, other: &QualityStats) {
        self.samples += other.samples;
        self.dropped += other.dropped;
        self.hit_sum += other.hit_sum;
        self.truth_sum += other.truth_sum;
        self.exact_matches += other.exact_matches;
        self.displacement_sum += other.displacement_sum;
        self.displacement_count += other.displacement_count;
        self.distance_err_sum += other.distance_err_sum;
        self.distance_err_count += other.distance_err_count;
    }

    /// The estimate as the STATS `quality` object.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("samples".to_string(), Json::Num(self.samples as f64));
        o.insert("dropped".to_string(), Json::Num(self.dropped as f64));
        o.insert("recall".to_string(), Json::Num(self.recall()));
        o.insert(
            "exact_matches".to_string(),
            Json::Num(self.exact_matches as f64),
        );
        o.insert(
            "mean_rank_displacement".to_string(),
            Json::Num(self.mean_displacement()),
        );
        o.insert(
            "mean_distance_error".to_string(),
            Json::Num(self.mean_distance_error()),
        );
        Json::Obj(o)
    }
}

/// "The winner came from rank r": a dense histogram over the rank (in
/// the polled-class or contacted-shard order, best first) of the source
/// that produced the final top-1 neighbor.
///
/// If `by_rank` is front-loaded the tail of the fan-out never decides
/// an answer and `p`/`s` can shrink; mass at high ranks means the poll
/// ordering is weak for this workload and pruning will cost recall.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RankHistogram {
    /// Wins per source rank (index 0 = the top-polled source).
    pub by_rank: Vec<u64>,
    /// Requests with no winner at all (empty answer).
    pub unresolved: u64,
}

impl RankHistogram {
    /// Record one request's winning rank (`None` = empty answer).
    pub fn record(&mut self, winner_rank: Option<usize>) {
        match winner_rank {
            Some(r) => {
                if self.by_rank.len() <= r {
                    self.by_rank.resize(r + 1, 0);
                }
                self.by_rank[r] += 1;
            }
            None => self.unresolved += 1,
        }
    }

    /// Total recorded requests (wins + unresolved).
    pub fn total(&self) -> u64 {
        self.by_rank.iter().sum::<u64>() + self.unresolved
    }

    /// Fraction of resolved requests won by the top-ranked source —
    /// 1.0 means fan-out past rank 0 never changed an answer.
    pub fn top1_fraction(&self) -> f64 {
        let wins: u64 = self.by_rank.iter().sum();
        if wins == 0 {
            return 1.0;
        }
        self.by_rank.first().copied().unwrap_or(0) as f64 / wins as f64
    }

    /// Fold another histogram in.
    pub fn merge(&mut self, other: &RankHistogram) {
        if self.by_rank.len() < other.by_rank.len() {
            self.by_rank.resize(other.by_rank.len(), 0);
        }
        for (a, b) in self.by_rank.iter_mut().zip(&other.by_rank) {
            *a += *b;
        }
        self.unresolved += other.unresolved;
    }

    /// As a STATS object.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("total".to_string(), Json::Num(self.total() as f64));
        o.insert("unresolved".to_string(), Json::Num(self.unresolved as f64));
        o.insert("top1_fraction".to_string(), Json::Num(self.top1_fraction()));
        o.insert(
            "by_rank".to_string(),
            Json::Arr(self.by_rank.iter().map(|&c| Json::Num(c as f64)).collect()),
        );
        Json::Obj(o)
    }
}

/// Candidate-survival funnel: of the candidates the scan touched, how
/// many survived into the returned top-k.  Under SQ8/PQ the scan is
/// approximate and the rerank exact, so a falling survival ratio at
/// fixed `k` means the compressed distances are ordering candidates
/// badly — the knob to watch before recall moves.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SurvivalStats {
    /// Candidates scanned (funnel entry).
    pub candidates: u64,
    /// Neighbors returned (funnel exit).
    pub survivors: u64,
}

impl SurvivalStats {
    /// Record one request's funnel.
    pub fn record(&mut self, candidates: usize, survivors: usize) {
        self.candidates += candidates as u64;
        self.survivors += survivors as u64;
    }

    /// Exit/entry ratio (1.0 when nothing was scanned).
    pub fn ratio(&self) -> f64 {
        if self.candidates == 0 {
            1.0
        } else {
            self.survivors as f64 / self.candidates as f64
        }
    }

    /// Fold another funnel in.
    pub fn merge(&mut self, other: &SurvivalStats) {
        self.candidates += other.candidates;
        self.survivors += other.survivors;
    }

    /// As a STATS object.
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("candidates".to_string(), Json::Num(self.candidates as f64));
        o.insert("survivors".to_string(), Json::Num(self.survivors as f64));
        o.insert("ratio".to_string(), Json::Num(self.ratio()));
        Json::Obj(o)
    }
}

struct ShadowState<T> {
    queue: VecDeque<T>,
    closed: bool,
    dropped: u64,
}

/// Bounded drop-oldest handoff from the serving path to the shadow
/// worker.
///
/// The hot path calls [`ShadowQueue::push`], which never blocks: when
/// the queue is full the *oldest* pending sample is dropped (and
/// counted) so the estimate tracks recent traffic under overload.  The
/// shadow worker blocks in [`ShadowQueue::pop`], which returns `None`
/// only once the queue is closed *and* drained — shutdown therefore
/// finishes every accepted sample deterministically.
pub struct ShadowQueue<T> {
    state: Mutex<ShadowState<T>>,
    ready: Condvar,
    capacity: usize,
}

impl<T> ShadowQueue<T> {
    /// A queue holding at most `capacity` pending samples (min 1).
    pub fn new(capacity: usize) -> Self {
        ShadowQueue {
            state: Mutex::new(ShadowState {
                queue: VecDeque::new(),
                closed: false,
                dropped: 0,
            }),
            ready: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Enqueue a sample, dropping the oldest pending one when full.
    /// Never blocks beyond the queue lock; a sample pushed after
    /// [`ShadowQueue::close`] is counted as dropped.
    pub fn push(&self, item: T) {
        let mut st = lock_unpoisoned(&self.state);
        if st.closed {
            st.dropped += 1;
            return;
        }
        if st.queue.len() >= self.capacity {
            st.queue.pop_front();
            st.dropped += 1;
        }
        st.queue.push_back(item);
        drop(st);
        self.ready.notify_one();
    }

    /// Dequeue the next sample, blocking while the queue is open and
    /// empty; `None` means closed-and-drained (worker exit signal).
    pub fn pop(&self) -> Option<T> {
        let mut st = lock_unpoisoned(&self.state);
        loop {
            if let Some(item) = st.queue.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            // timed wait so a lost notification can never wedge the
            // worker (the same defensive idiom as the batcher)
            let (guard, _timeout) =
                wait_timeout_unpoisoned(&self.ready, st, Duration::from_millis(50));
            st = guard;
        }
    }

    /// Close the queue: pushes become drops, `pop` drains then returns
    /// `None`.
    pub fn close(&self) {
        let mut st = lock_unpoisoned(&self.state);
        st.closed = true;
        drop(st);
        self.ready.notify_all();
    }

    /// Samples dropped so far (overload + post-close pushes).
    pub fn dropped(&self) -> u64 {
        lock_unpoisoned(&self.state).dropped
    }

    /// Pending samples.
    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.state).queue.len()
    }

    /// True when nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Deterministic 1-in-`every` admission counter for quality sampling,
/// mirroring the trace sampler: request `n` (1-based) is sampled iff
/// `n % every == 0`, `every = 0` disables sampling.
pub fn sample_hit(admitted: u64, every: u64) -> bool {
    every > 0 && admitted % every == 0
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn nb(id: u32, distance: f32) -> Neighbor {
        Neighbor { id, distance }
    }

    #[test]
    fn identical_answers_score_perfect() {
        let mut q = QualityStats::default();
        let answer = vec![nb(3, 0.1), nb(7, 0.2), nb(1, 0.4)];
        q.record_comparison(&answer, &answer);
        assert_eq!(q.samples, 1);
        assert_eq!(q.exact_matches, 1);
        assert_eq!(q.recall(), 1.0);
        assert_eq!(q.mean_displacement(), 0.0);
        assert_eq!(q.mean_distance_error(), 0.0);
    }

    #[test]
    fn empty_stats_read_as_no_evidence_of_loss() {
        let q = QualityStats::default();
        assert_eq!(q.recall(), 1.0);
        assert_eq!(q.mean_displacement(), 0.0);
        assert_eq!(q.mean_distance_error(), 0.0);
    }

    #[test]
    fn missing_and_displaced_neighbors_are_charged() {
        let mut q = QualityStats::default();
        // exact top-3: 1, 2, 3; served got 2 (displaced by 1), 1
        // (displaced by 1) and 9 (absent -> charged the cap 3)
        let served = vec![nb(2, 0.2), nb(1, 0.1), nb(9, 0.9)];
        let exact = vec![nb(1, 0.1), nb(2, 0.2), nb(3, 0.3)];
        q.record_comparison(&served, &exact);
        assert_eq!(q.hit_sum, 2);
        assert_eq!(q.truth_sum, 3);
        assert_eq!(q.exact_matches, 0);
        assert!((q.recall() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(q.displacement_sum, 1 + 1 + 3);
        assert_eq!(q.displacement_count, 3);
        // rank 0: 0.2 vs 0.1 -> 1.0; rank 1: 0.1 vs 0.2 -> clamped 0;
        // rank 2: 0.9 vs 0.3 -> ~2.0 (f32 literals are inexact, so the
        // ratio lands ~1e-7 off — hence the loose tolerance)
        assert!((q.mean_distance_error() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn merge_matches_sequential_recording() {
        let served_a = vec![nb(2, 0.2), nb(9, 0.9)];
        let served_b = vec![nb(1, 0.1)];
        let exact = vec![nb(1, 0.1), nb(2, 0.2)];
        let mut whole = QualityStats::default();
        whole.record_comparison(&served_a, &exact);
        whole.record_comparison(&served_b, &exact);
        let mut left = QualityStats::default();
        left.record_comparison(&served_a, &exact);
        let mut right = QualityStats::default();
        right.record_comparison(&served_b, &exact);
        left.merge(&right);
        assert_eq!(left, whole);
    }

    #[test]
    fn rank_histogram_counts_and_top1_fraction() {
        let mut h = RankHistogram::default();
        h.record(Some(0));
        h.record(Some(0));
        h.record(Some(2));
        h.record(None);
        assert_eq!(h.by_rank, vec![2, 0, 1]);
        assert_eq!(h.unresolved, 1);
        assert_eq!(h.total(), 4);
        assert!((h.top1_fraction() - 2.0 / 3.0).abs() < 1e-12);

        let mut other = RankHistogram::default();
        other.record(Some(1));
        h.merge(&other);
        assert_eq!(h.by_rank, vec![2, 1, 1]);
    }

    #[test]
    fn empty_rank_histogram_is_benign() {
        let h = RankHistogram::default();
        assert_eq!(h.total(), 0);
        assert_eq!(h.top1_fraction(), 1.0);
    }

    #[test]
    fn survival_ratio() {
        let mut s = SurvivalStats::default();
        s.record(100, 10);
        s.record(50, 10);
        assert_eq!(s.candidates, 150);
        assert_eq!(s.survivors, 20);
        assert!((s.ratio() - 20.0 / 150.0).abs() < 1e-12);
        assert_eq!(SurvivalStats::default().ratio(), 1.0);
    }

    #[test]
    fn shadow_queue_drops_oldest_when_full() {
        let q = ShadowQueue::new(2);
        q.push(1);
        q.push(2);
        q.push(3); // drops 1
        assert_eq!(q.dropped(), 1);
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        q.close();
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn shadow_queue_close_drains_then_ends() {
        let q = ShadowQueue::new(8);
        q.push(10);
        q.push(20);
        q.close();
        // pending samples still come out after close...
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.pop(), Some(20));
        // ...then the worker-exit signal
        assert_eq!(q.pop(), None);
        // and a late push is a counted drop, not a revival
        q.push(30);
        assert_eq!(q.pop(), None);
        assert_eq!(q.dropped(), 1);
    }

    #[test]
    fn shadow_queue_unblocks_waiting_consumer() {
        let q = Arc::new(ShadowQueue::<u32>::new(4));
        let qc = Arc::clone(&q);
        let consumer = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(v) = qc.pop() {
                got.push(v);
            }
            got
        });
        for v in 0..5u32 {
            q.push(v);
            std::thread::sleep(Duration::from_millis(1));
        }
        q.close();
        let got = consumer.join().unwrap();
        // capacity 4 with a sleeping producer: normally all 5 arrive,
        // but the scheduler may batch pushes and drop the oldest —
        // either way the count plus drops is conserved and order holds
        assert_eq!(got.len() as u64 + q.dropped(), 5);
        assert!(got.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn sampler_is_deterministic_and_gated() {
        assert!(!sample_hit(1, 0));
        assert!(!sample_hit(0x7fff_ffff, 0));
        assert!(sample_hit(1, 1));
        assert!(sample_hit(2, 1));
        assert!(!sample_hit(1, 3));
        assert!(!sample_hit(2, 3));
        assert!(sample_hit(3, 3));
        assert!(sample_hit(6, 3));
    }
}
