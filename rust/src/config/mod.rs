//! Configuration system: JSON files + programmatic overrides.
//!
//! One [`AppConfig`] drives the launcher (`amsearch` CLI): dataset
//! selection/generation, index hyper-parameters, scoring backend, and
//! coordinator tuning.  Every field has a sane default so a bare
//! `amsearch serve` works out of the box.  The file format is JSON
//! (parsed by the in-tree `util::json`; the offline build has no
//! serde/toml):
//!
//! ```json
//! {
//!   "dataset": {"kind": "sift_like", "n": 16384, "n_queries": 256},
//!   "index":   {"n_classes": 64, "top_p": 2, "allocation": "random"},
//!   "serve":   {"max_batch": 8, "workers": 2},
//!   "backend": {"kind": "native", "artifacts_dir": "artifacts"}
//! }
//! ```

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::index::IndexParams;
use crate::memory::StorageRule;
use crate::partition::Allocation;
use crate::quant::ScanPrecision;
use crate::runtime::Backend;
use crate::search::Metric;
use crate::store::{StoreMode, StoreOptions, DEFAULT_CACHE_BYTES};
use crate::util::json::Json;

/// Which workload generator to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// Paper §3: sparse 0/1 i.i.d. patterns.
    SparseSynthetic,
    /// Paper §4: dense ±1 i.i.d. patterns.
    DenseSynthetic,
    /// SIFT1M-like clustered surrogate (128-d).
    SiftLike,
    /// GIST1M-like clustered surrogate (960-d).
    GistLike,
    /// MNIST-like surrogate (784-d).
    MnistLike,
    /// Santander-like sparse binary surrogate (369-d).
    SantanderLike,
    /// Load fvecs files from `data_dir`.
    Fvecs,
}

impl std::str::FromStr for DatasetKind {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "sparse_synthetic" => Ok(DatasetKind::SparseSynthetic),
            "dense_synthetic" => Ok(DatasetKind::DenseSynthetic),
            "sift_like" => Ok(DatasetKind::SiftLike),
            "gist_like" => Ok(DatasetKind::GistLike),
            "mnist_like" => Ok(DatasetKind::MnistLike),
            "santander_like" => Ok(DatasetKind::SantanderLike),
            "fvecs" => Ok(DatasetKind::Fvecs),
            other => Err(Error::Config(format!("unknown dataset kind '{other}'"))),
        }
    }
}

/// Dataset section.
#[derive(Debug, Clone)]
pub struct DatasetConfig {
    /// Generator / loader selector.
    pub kind: DatasetKind,
    /// Database size (generators).
    pub n: usize,
    /// Number of queries.
    pub n_queries: usize,
    /// Dimension (sparse/dense synthetic only; surrogates fix their own).
    pub dim: usize,
    /// Expected ones per sparse pattern (`c`).
    pub sparse_ones: f64,
    /// RNG seed.
    pub seed: u64,
    /// Directory holding fvecs files (`base.fvecs`, `query.fvecs`).
    pub data_dir: Option<PathBuf>,
    /// Apply §5.2 centering + unit-sphere projection.
    pub normalize: bool,
}

impl Default for DatasetConfig {
    fn default() -> Self {
        DatasetConfig {
            kind: DatasetKind::SiftLike,
            n: 16384,
            n_queries: 256,
            dim: 128,
            sparse_ones: 8.0,
            seed: 42,
            data_dir: None,
            normalize: false,
        }
    }
}

/// Index section (mirrors [`IndexParams`]).
#[derive(Debug, Clone)]
pub struct IndexConfig {
    /// Number of classes `q`.
    pub n_classes: usize,
    /// Default poll depth `p`.
    pub top_p: usize,
    /// Default neighbors returned per query `k`.
    pub top_k: usize,
    /// Storage rule.
    pub rule: StorageRule,
    /// Allocation strategy.
    pub allocation: Allocation,
    /// Scan metric.
    pub metric: Metric,
    /// Greedy class-size cap factor.
    pub greedy_cap_factor: Option<f64>,
    /// Candidate-scan precision (JSON: `"precision": "exact"|"sq8"|"pq"`
    /// plus `"rerank"`, `"pq_m"`, `"pq_bits"`).
    pub precision: ScanPrecision,
}

impl Default for IndexConfig {
    fn default() -> Self {
        IndexConfig {
            n_classes: 64,
            top_p: 1,
            top_k: 1,
            rule: StorageRule::Sum,
            allocation: Allocation::Random,
            metric: Metric::SqL2,
            greedy_cap_factor: None,
            precision: ScanPrecision::Exact,
        }
    }
}

impl IndexConfig {
    /// Convert to runtime [`IndexParams`].
    pub fn to_params(&self) -> IndexParams {
        IndexParams {
            n_classes: self.n_classes,
            top_p: self.top_p,
            top_k: self.top_k,
            rule: self.rule,
            allocation: self.allocation,
            metric: self.metric,
            greedy_cap_factor: self.greedy_cap_factor,
            precision: self.precision,
        }
    }
}

/// Assemble a [`ScanPrecision`] from its four knobs (shared by the JSON
/// parser and the CLI override flags).
pub fn scan_precision_from_knobs(
    mode: &str,
    rerank: usize,
    pq_m: usize,
    pq_bits: usize,
) -> Result<ScanPrecision> {
    let precision = match mode {
        "exact" | "f32" => ScanPrecision::Exact,
        "sq8" => ScanPrecision::Sq8 { rerank },
        "pq" => ScanPrecision::Pq { m: pq_m, bits: pq_bits, rerank },
        other => {
            return Err(Error::Config(format!(
                "unknown scan precision '{other}' (exact|sq8|pq)"
            )))
        }
    };
    precision.validate_params()?;
    Ok(precision)
}

/// Coordinator section.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Max dynamic batch size.
    pub max_batch: usize,
    /// Batcher deadline in microseconds.
    pub max_wait_us: u64,
    /// Worker threads.
    pub workers: usize,
    /// Request queue bound.
    pub queue_depth: usize,
    /// Trace-sample every Nth request (`0` = tracing off).
    pub trace_sample: u64,
    /// Force-sample requests slower than this many milliseconds
    /// (`0` = no slow-query forcing).
    pub trace_slow_ms: u64,
    /// Shadow-execute an exact scan for every Nth request and fold the
    /// comparison into the online recall estimate (`0` = off).
    pub quality_sample: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            max_wait_us: 200,
            workers: 2,
            queue_depth: 1024,
            trace_sample: 0,
            trace_slow_ms: 0,
            quality_sample: 0,
        }
    }
}

impl ServeConfig {
    /// Convert to the coordinator's config struct.
    pub fn to_coordinator(&self) -> crate::coordinator::CoordinatorConfig {
        crate::coordinator::CoordinatorConfig {
            max_batch: self.max_batch,
            max_wait_us: self.max_wait_us,
            workers: self.workers,
            queue_depth: self.queue_depth,
            quality_sample: self.quality_sample,
        }
    }
}

/// Vector-store section: where the exact member matrices of a *loaded*
/// index live (`serve --index`, `query --index`, `serve-cluster`).
/// Ignored when the index is built in-process — a fresh build is always
/// resident.
#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// resident | paged.
    pub mode: StoreMode,
    /// Extent-cache budget in MiB (paged mode only).
    pub cache_mb: u64,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig {
            mode: StoreMode::Resident,
            cache_mb: DEFAULT_CACHE_BYTES >> 20,
        }
    }
}

impl StoreConfig {
    /// Convert to the store layer's option struct.
    pub fn to_options(&self) -> StoreOptions {
        StoreOptions {
            mode: self.mode,
            cache_bytes: self.cache_mb.saturating_mul(1024 * 1024),
        }
    }
}

/// Backend section.
#[derive(Debug, Clone)]
pub struct BackendConfig {
    /// native | pjrt.
    pub kind: Backend,
    /// AOT artifacts directory.
    pub artifacts_dir: PathBuf,
}

impl Default for BackendConfig {
    fn default() -> Self {
        BackendConfig { kind: Backend::Native, artifacts_dir: PathBuf::from("artifacts") }
    }
}

/// Top-level application configuration.
#[derive(Debug, Clone, Default)]
pub struct AppConfig {
    /// Dataset selection.
    pub dataset: DatasetConfig,
    /// Index hyper-parameters.
    pub index: IndexConfig,
    /// Serving parameters.
    pub serve: ServeConfig,
    /// Scoring backend.
    pub backend: BackendConfig,
    /// Vector-store selection for loaded indices.
    pub store: StoreConfig,
}

fn get_usize(obj: &Json, key: &str, default: usize) -> Result<usize> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_usize()
            .ok_or_else(|| Error::Config(format!("'{key}' must be a non-negative integer"))),
    }
}

fn get_u64(obj: &Json, key: &str, default: u64) -> Result<u64> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_u64()
            .ok_or_else(|| Error::Config(format!("'{key}' must be a non-negative integer"))),
    }
}

fn get_f64(obj: &Json, key: &str, default: f64) -> Result<f64> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| Error::Config(format!("'{key}' must be a number"))),
    }
}

fn get_bool(obj: &Json, key: &str, default: bool) -> Result<bool> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| Error::Config(format!("'{key}' must be a boolean"))),
    }
}

fn get_parsed<T: std::str::FromStr<Err = Error>>(
    obj: &Json,
    key: &str,
    default: T,
) -> Result<T> {
    match obj.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_str()
            .ok_or_else(|| Error::Config(format!("'{key}' must be a string")))?
            .parse::<T>(),
    }
}

impl AppConfig {
    /// Load from a JSON file.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Config(format!("cannot read {}: {e}", path.display())))?;
        Self::from_json(&text)
    }

    /// Parse from JSON text (missing fields take defaults).
    pub fn from_json(text: &str) -> Result<Self> {
        let root = Json::parse(text).map_err(|e| Error::Config(e.to_string()))?;
        let empty = Json::Obj(Default::default());
        let mut cfg = AppConfig::default();

        let ds = root.get("dataset").unwrap_or(&empty);
        cfg.dataset.kind = get_parsed(ds, "kind", cfg.dataset.kind.clone_kind())?;
        cfg.dataset.n = get_usize(ds, "n", cfg.dataset.n)?;
        cfg.dataset.n_queries = get_usize(ds, "n_queries", cfg.dataset.n_queries)?;
        cfg.dataset.dim = get_usize(ds, "dim", cfg.dataset.dim)?;
        cfg.dataset.sparse_ones = get_f64(ds, "sparse_ones", cfg.dataset.sparse_ones)?;
        cfg.dataset.seed = get_u64(ds, "seed", cfg.dataset.seed)?;
        cfg.dataset.normalize = get_bool(ds, "normalize", cfg.dataset.normalize)?;
        if let Some(v) = ds.get("data_dir") {
            let s = v
                .as_str()
                .ok_or_else(|| Error::Config("'data_dir' must be a string".into()))?;
            cfg.dataset.data_dir = Some(PathBuf::from(s));
        }

        let ix = root.get("index").unwrap_or(&empty);
        cfg.index.n_classes = get_usize(ix, "n_classes", cfg.index.n_classes)?;
        cfg.index.top_p = get_usize(ix, "top_p", cfg.index.top_p)?;
        cfg.index.top_k = get_usize(ix, "top_k", cfg.index.top_k)?;
        cfg.index.rule = get_parsed(ix, "rule", cfg.index.rule)?;
        cfg.index.allocation = get_parsed(ix, "allocation", cfg.index.allocation)?;
        cfg.index.metric = get_parsed(ix, "metric", cfg.index.metric)?;
        if let Some(v) = ix.get("greedy_cap_factor") {
            cfg.index.greedy_cap_factor = Some(
                v.as_f64()
                    .ok_or_else(|| Error::Config("'greedy_cap_factor' must be a number".into()))?,
            );
        }
        match ix.get("precision") {
            Some(v) => {
                let mode = v.as_str().ok_or_else(|| {
                    Error::Config("'precision' must be a string".into())
                })?;
                cfg.index.precision = scan_precision_from_knobs(
                    mode,
                    get_usize(ix, "rerank", 0)?,
                    get_usize(ix, "pq_m", 8)?,
                    get_usize(ix, "pq_bits", 8)?,
                )?;
            }
            // the quant knobs mean nothing without a mode — reject
            // instead of silently serving at a different precision
            None if ix.get("rerank").is_some()
                || ix.get("pq_m").is_some()
                || ix.get("pq_bits").is_some() =>
            {
                return Err(Error::Config(
                    "'rerank'/'pq_m'/'pq_bits' require 'precision' \
                     (exact|sq8|pq) in the index section"
                        .into(),
                ));
            }
            None => {}
        }

        let sv = root.get("serve").unwrap_or(&empty);
        cfg.serve.max_batch = get_usize(sv, "max_batch", cfg.serve.max_batch)?;
        cfg.serve.max_wait_us = get_u64(sv, "max_wait_us", cfg.serve.max_wait_us)?;
        cfg.serve.workers = get_usize(sv, "workers", cfg.serve.workers)?;
        cfg.serve.queue_depth = get_usize(sv, "queue_depth", cfg.serve.queue_depth)?;
        cfg.serve.trace_sample = get_u64(sv, "trace_sample", cfg.serve.trace_sample)?;
        cfg.serve.trace_slow_ms =
            get_u64(sv, "trace_slow_ms", cfg.serve.trace_slow_ms)?;
        cfg.serve.quality_sample =
            get_u64(sv, "quality_sample", cfg.serve.quality_sample)?;

        let st = root.get("store").unwrap_or(&empty);
        match st.get("mode") {
            Some(v) => {
                let s = v
                    .as_str()
                    .ok_or_else(|| Error::Config("'mode' must be a string".into()))?;
                cfg.store.mode = StoreMode::parse(s)?;
                cfg.store.cache_mb = get_u64(st, "cache_mb", cfg.store.cache_mb)?;
            }
            // a cache budget means nothing without the paged mode —
            // reject instead of silently serving resident
            None if st.get("cache_mb").is_some() => {
                return Err(Error::Config(
                    "'cache_mb' requires 'mode' (resident|paged) in the \
                     store section"
                        .into(),
                ));
            }
            None => {}
        }

        let be = root.get("backend").unwrap_or(&empty);
        cfg.backend.kind = get_parsed(be, "kind", cfg.backend.kind)?;
        if let Some(v) = be.get("artifacts_dir") {
            let s = v
                .as_str()
                .ok_or_else(|| Error::Config("'artifacts_dir' must be a string".into()))?;
            cfg.backend.artifacts_dir = PathBuf::from(s);
        }

        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field validation.
    pub fn validate(&self) -> Result<()> {
        if self.dataset.n == 0 {
            return Err(Error::Config("dataset.n must be > 0".into()));
        }
        if self.index.n_classes > self.dataset.n {
            return Err(Error::Config(format!(
                "index.n_classes {} > dataset.n {}",
                self.index.n_classes, self.dataset.n
            )));
        }
        if self.serve.max_batch == 0 || self.serve.workers == 0 {
            return Err(Error::Config("serve.max_batch/workers must be > 0".into()));
        }
        if self.dataset.kind == DatasetKind::Fvecs && self.dataset.data_dir.is_none() {
            return Err(Error::Config("dataset.kind=fvecs requires data_dir".into()));
        }
        Ok(())
    }
}

impl DatasetKind {
    fn clone_kind(self) -> DatasetKind {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        AppConfig::default().validate().unwrap();
    }

    #[test]
    fn full_json_parses() {
        let cfg = AppConfig::from_json(
            r#"{
                "dataset": {"kind": "dense_synthetic", "n": 4096, "dim": 64,
                             "seed": 7, "normalize": true},
                "index": {"n_classes": 32, "top_p": 4, "rule": "max",
                           "allocation": "greedy", "metric": "neg_dot",
                           "greedy_cap_factor": 2.0},
                "serve": {"max_batch": 16, "workers": 4},
                "backend": {"kind": "pjrt", "artifacts_dir": "a/b"}
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.dataset.kind, DatasetKind::DenseSynthetic);
        assert_eq!(cfg.dataset.n, 4096);
        assert!(cfg.dataset.normalize);
        assert_eq!(cfg.index.n_classes, 32);
        assert_eq!(cfg.index.rule, StorageRule::Max);
        assert_eq!(cfg.index.allocation, Allocation::Greedy);
        assert_eq!(cfg.index.metric, Metric::NegDot);
        assert_eq!(cfg.index.greedy_cap_factor, Some(2.0));
        assert_eq!(cfg.serve.max_batch, 16);
        assert_eq!(cfg.backend.kind, Backend::Pjrt);
        assert_eq!(cfg.backend.artifacts_dir, PathBuf::from("a/b"));
    }

    #[test]
    fn partial_json_uses_defaults() {
        let cfg =
            AppConfig::from_json(r#"{"index": {"n_classes": 10}}"#).unwrap();
        assert_eq!(cfg.index.n_classes, 10);
        assert_eq!(cfg.serve.max_batch, 8);
        assert_eq!(cfg.serve.trace_sample, 0, "tracing defaults off");
        assert_eq!(cfg.serve.trace_slow_ms, 0);
        assert_eq!(cfg.serve.quality_sample, 0, "quality sampling defaults off");
        assert_eq!(cfg.dataset.kind, DatasetKind::SiftLike);
    }

    #[test]
    fn trace_knobs_parse() {
        let cfg = AppConfig::from_json(
            r#"{"serve": {"trace_sample": 100, "trace_slow_ms": 250}}"#,
        )
        .unwrap();
        assert_eq!(cfg.serve.trace_sample, 100);
        assert_eq!(cfg.serve.trace_slow_ms, 250);
        assert!(
            AppConfig::from_json(r#"{"serve": {"trace_sample": -1}}"#).is_err()
        );
    }

    #[test]
    fn quality_knob_parses_and_threads_through() {
        let cfg =
            AppConfig::from_json(r#"{"serve": {"quality_sample": 10}}"#).unwrap();
        assert_eq!(cfg.serve.quality_sample, 10);
        assert_eq!(cfg.serve.to_coordinator().quality_sample, 10);
        assert!(
            AppConfig::from_json(r#"{"serve": {"quality_sample": -2}}"#).is_err()
        );
    }

    #[test]
    fn store_section_parses_and_converts() {
        let cfg = AppConfig::from_json(
            r#"{"store": {"mode": "paged", "cache_mb": 8}}"#,
        )
        .unwrap();
        assert_eq!(cfg.store.mode, StoreMode::Paged);
        let opts = cfg.store.to_options();
        assert_eq!(opts.mode, StoreMode::Paged);
        assert_eq!(opts.cache_bytes, 8 * 1024 * 1024);

        // defaults: resident, 64 MiB budget
        let cfg = AppConfig::from_json("{}").unwrap();
        assert_eq!(cfg.store.mode, StoreMode::Resident);
        assert_eq!(cfg.store.to_options().cache_bytes, DEFAULT_CACHE_BYTES);

        // bad mode and orphan cache knob are rejected
        assert!(AppConfig::from_json(r#"{"store": {"mode": "mmap"}}"#).is_err());
        assert!(AppConfig::from_json(r#"{"store": {"cache_mb": 8}}"#).is_err());
    }

    #[test]
    fn invalid_rejected() {
        assert!(AppConfig::from_json(r#"{"dataset": {"n": 0}}"#).is_err());
        assert!(AppConfig::from_json(
            r#"{"dataset": {"n": 10}, "index": {"n_classes": 20}}"#
        )
        .is_err());
        assert!(AppConfig::from_json(r#"{"dataset": {"kind": "fvecs"}}"#).is_err());
        assert!(AppConfig::from_json(r#"{"index": {"rule": "median"}}"#).is_err());
        assert!(AppConfig::from_json("{ not json").is_err());
    }

    #[test]
    fn to_params_matches() {
        let cfg =
            AppConfig::from_json(r#"{"index": {"n_classes": 12, "top_p": 3}}"#).unwrap();
        let p = cfg.index.to_params();
        assert_eq!(p.n_classes, 12);
        assert_eq!(p.top_p, 3);
        assert_eq!(p.top_k, 1); // default when unspecified
    }

    #[test]
    fn top_k_parses_and_flows_to_params() {
        let cfg = AppConfig::from_json(r#"{"index": {"top_k": 5}}"#).unwrap();
        assert_eq!(cfg.index.top_k, 5);
        assert_eq!(cfg.index.to_params().top_k, 5);
    }

    #[test]
    fn precision_parses_and_flows_to_params() {
        let cfg = AppConfig::from_json(
            r#"{"index": {"precision": "sq8", "rerank": 64}}"#,
        )
        .unwrap();
        assert_eq!(cfg.index.precision, ScanPrecision::Sq8 { rerank: 64 });
        assert_eq!(
            cfg.index.to_params().precision,
            ScanPrecision::Sq8 { rerank: 64 }
        );

        let cfg = AppConfig::from_json(
            r#"{"index": {"precision": "pq", "pq_m": 16, "pq_bits": 4}}"#,
        )
        .unwrap();
        assert_eq!(
            cfg.index.precision,
            ScanPrecision::Pq { m: 16, bits: 4, rerank: 0 }
        );

        // default when unspecified
        let cfg = AppConfig::from_json("{}").unwrap();
        assert_eq!(cfg.index.precision, ScanPrecision::Exact);

        // bad values rejected
        assert!(AppConfig::from_json(r#"{"index": {"precision": "fp4"}}"#).is_err());
        assert!(AppConfig::from_json(
            r#"{"index": {"precision": "pq", "pq_bits": 12}}"#
        )
        .is_err());
        // quant knobs without a mode are rejected, not silently dropped
        assert!(AppConfig::from_json(r#"{"index": {"rerank": 64}}"#).is_err());
        assert!(AppConfig::from_json(r#"{"index": {"pq_m": 4}}"#).is_err());
    }
}
