//! The paged vector store: class-extent data files (`*.amdat`) and the
//! pread-backed, LRU-cached reader that serves the exact scan/rerank
//! from disk.
//!
//! On-disk layout (all integers little-endian; full spec in
//! `docs/STORE_FORMAT.md`):
//!
//! ```text
//! magic     8B   "AMDATAF1"
//! dim       u32
//! q         u32  number of classes
//! n         u64  number of vectors
//! table     q × (offset u64, rows u64, fnv u64)
//! table_fnv u64  FNV-1a of everything before it
//! ...zero padding to the first 4096-byte boundary...
//! extent 0  rows(0) * dim * f32, members-list order, 4096-aligned
//! ...zero padding...
//! extent 1  ...
//! ```
//!
//! Each extent is one class's member rows, contiguous and
//! 4096-aligned, so the class-major batch scan turns into **one
//! sequential positional read per polled class per batch**.  Extents
//! carry their own FNV-1a checksum, verified on every fetch; the
//! companion `.amidx` records the file length and `table_fnv`, binding
//! the pair so a swapped or stale data file is rejected at open.
//!
//! I/O is explicit `pread` (`std::os::unix::fs::FileExt::read_exact_at`)
//! — positional, safe, shareable across threads without seeking.  No
//! mmap: no `unsafe`, no SIGBUS-on-truncation hazard (and amlint's
//! `store_io` rule keeps it that way).

use std::collections::HashMap;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::data::dataset::Dataset;
use crate::error::{Error, Result};
use crate::partition::Partition;
use crate::util::sync::lock_unpoisoned;

use super::{ClassRows, Fnv, StoreStats};

/// Magic prefix of a class-extent data file.
pub(crate) const DATA_MAGIC: &[u8; 8] = b"AMDATAF1";

/// Extent alignment: every class's rows start on a 4096-byte boundary
/// (the common page / logical-block size), so a fetch is one aligned
/// sequential read.
pub(crate) const DATA_ALIGN: u64 = 4096;

/// Bytes of the fixed header before the extent table.
const HEADER_LEN: u64 = 8 + 4 + 4 + 8;

/// Bytes of one extent-table entry.
const TABLE_ENTRY_LEN: u64 = 8 + 8 + 8;

const PAD: [u8; DATA_ALIGN as usize] = [0u8; DATA_ALIGN as usize];

fn align_up(x: u64, a: u64) -> u64 {
    (x + a - 1) / a * a
}

/// One class's extent: where its member rows live in the data file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct Extent {
    /// Byte offset of the first row (4096-aligned when `rows > 0`).
    pub(crate) offset: u64,
    /// Number of member rows.
    pub(crate) rows: u64,
    /// FNV-1a 64 of the extent's payload bytes.
    pub(crate) fnv: u64,
}

/// Write the class-extent data file for `data` partitioned by
/// `partition`.  Returns `(file_len, table_fnv)` — the values the
/// companion `.amidx` header records to bind the pair.
pub(crate) fn write_data_file(
    path: &Path,
    data: &Dataset,
    partition: &Partition,
) -> Result<(u64, u64)> {
    let dim = data.dim();
    let q = partition.n_classes();
    let n = partition.n_vectors();
    // pass 1: per-class payload checksums and aligned extent offsets
    let table_end = HEADER_LEN + q as u64 * TABLE_ENTRY_LEN + 8;
    let mut cursor = align_up(table_end, DATA_ALIGN);
    let mut extents = Vec::with_capacity(q);
    for ci in 0..q {
        let members = partition.members(ci);
        let mut h = Fnv::new();
        for &vid in members {
            for &x in data.get(vid as usize) {
                h.update(&x.to_le_bytes());
            }
        }
        extents.push(Extent {
            offset: cursor,
            rows: members.len() as u64,
            fnv: h.value(),
        });
        let len = (members.len() * dim * 4) as u64;
        cursor = align_up(cursor + len, DATA_ALIGN);
    }
    let file_len = cursor;
    // pass 2: stream the file out
    let file = std::fs::File::create(path)
        .map_err(|e| Error::Data(format!("cannot create {}: {e}", path.display())))?;
    let mut out = std::io::BufWriter::new(file);
    let mut h = Fnv::new();
    let mut put = |out: &mut std::io::BufWriter<std::fs::File>,
                   h: &mut Fnv,
                   b: &[u8]|
     -> Result<()> {
        h.update(b);
        out.write_all(b)?;
        Ok(())
    };
    put(&mut out, &mut h, DATA_MAGIC)?;
    put(&mut out, &mut h, &(dim as u32).to_le_bytes())?;
    put(&mut out, &mut h, &(q as u32).to_le_bytes())?;
    put(&mut out, &mut h, &(n as u64).to_le_bytes())?;
    for e in &extents {
        put(&mut out, &mut h, &e.offset.to_le_bytes())?;
        put(&mut out, &mut h, &e.rows.to_le_bytes())?;
        put(&mut out, &mut h, &e.fnv.to_le_bytes())?;
    }
    let table_fnv = h.value();
    out.write_all(&table_fnv.to_le_bytes())?;
    let mut pos = table_end;
    for (ci, e) in extents.iter().enumerate() {
        let mut gap = e.offset - pos;
        while gap > 0 {
            let chunk = gap.min(DATA_ALIGN) as usize;
            out.write_all(&PAD[..chunk])?;
            gap -= chunk as u64;
        }
        for &vid in partition.members(ci) {
            for &x in data.get(vid as usize) {
                out.write_all(&x.to_le_bytes())?;
            }
        }
        pos = e.offset + e.rows * dim as u64 * 4;
        let mut tail = align_up(pos, DATA_ALIGN) - pos;
        while tail > 0 {
            let chunk = tail.min(DATA_ALIGN) as usize;
            out.write_all(&PAD[..chunk])?;
            tail -= chunk as u64;
        }
        pos = align_up(pos, DATA_ALIGN);
    }
    out.flush()?;
    debug_assert_eq!(pos, file_len);
    Ok((file_len, table_fnv))
}

/// An opened, header-verified class-extent data file.
#[derive(Debug)]
pub(crate) struct DataFile {
    file: std::fs::File,
    pub(crate) dim: usize,
    pub(crate) q: usize,
    pub(crate) n: usize,
    pub(crate) extents: Vec<Extent>,
    pub(crate) table_fnv: u64,
    pub(crate) file_len: u64,
}

impl DataFile {
    /// Open and verify the header and extent table (magic, table
    /// checksum, extent alignment and bounds).
    pub(crate) fn open(path: &Path) -> Result<Self> {
        let mut file = std::fs::File::open(path).map_err(|e| {
            Error::Data(format!(
                "cannot open data file {}: {e} (paged/v5 indices need their \
                 .amdat sibling next to the .amidx)",
                path.display()
            ))
        })?;
        let file_len = file
            .metadata()
            .map_err(|e| Error::Data(format!("stat {}: {e}", path.display())))?
            .len();
        let mut head = [0u8; HEADER_LEN as usize];
        file.read_exact(&mut head)?;
        if &head[..8] != DATA_MAGIC {
            return Err(Error::Data(format!(
                "{} is not an amsearch data file",
                path.display()
            )));
        }
        let dim = u32::from_le_bytes([head[8], head[9], head[10], head[11]]) as usize;
        let q = u32::from_le_bytes([head[12], head[13], head[14], head[15]]) as usize;
        let n = u64::from_le_bytes([
            head[16], head[17], head[18], head[19], head[20], head[21], head[22],
            head[23],
        ]) as usize;
        let table_len = q as u64 * TABLE_ENTRY_LEN;
        if HEADER_LEN + table_len + 8 > file_len {
            return Err(Error::Data("data file truncated in extent table".into()));
        }
        let mut table = vec![0u8; table_len as usize];
        file.read_exact(&mut table)?;
        let mut stored_fnv = [0u8; 8];
        file.read_exact(&mut stored_fnv)?;
        let mut h = Fnv::new();
        h.update(&head);
        h.update(&table);
        let table_fnv = h.value();
        if table_fnv != u64::from_le_bytes(stored_fnv) {
            return Err(Error::Data(format!(
                "data file table corrupt: checksum {table_fnv:#x} != stored {:#x}",
                u64::from_le_bytes(stored_fnv)
            )));
        }
        let mut extents = Vec::with_capacity(q);
        let mut total_rows = 0u64;
        for (ci, e) in table.chunks_exact(TABLE_ENTRY_LEN as usize).enumerate() {
            let offset = u64::from_le_bytes([
                e[0], e[1], e[2], e[3], e[4], e[5], e[6], e[7],
            ]);
            let rows = u64::from_le_bytes([
                e[8], e[9], e[10], e[11], e[12], e[13], e[14], e[15],
            ]);
            let fnv = u64::from_le_bytes([
                e[16], e[17], e[18], e[19], e[20], e[21], e[22], e[23],
            ]);
            let len = rows
                .checked_mul(dim as u64 * 4)
                .ok_or_else(|| Error::Data("extent length overflow".into()))?;
            if rows > 0
                && (offset % DATA_ALIGN != 0
                    || offset
                        .checked_add(len)
                        .is_none_or(|end| end > file_len))
            {
                return Err(Error::Data(format!(
                    "class {ci} extent out of bounds or misaligned \
                     (offset {offset}, rows {rows})"
                )));
            }
            total_rows += rows;
            extents.push(Extent { offset, rows, fnv });
        }
        if total_rows != n as u64 {
            return Err(Error::Data(format!(
                "extent rows sum to {total_rows}, header says n = {n}"
            )));
        }
        Ok(DataFile { file, dim, q, n, extents, table_fnv, file_len })
    }

    /// Check this data file against the geometry and binding values the
    /// companion `.amidx` recorded.
    pub(crate) fn check_binding(
        &self,
        dim: usize,
        q: usize,
        n: usize,
        data_len: u64,
        table_fnv: u64,
    ) -> Result<()> {
        if self.dim != dim || self.q != q || self.n != n {
            return Err(Error::Data(format!(
                "data file geometry (dim {}, q {}, n {}) does not match the \
                 index (dim {dim}, q {q}, n {n})",
                self.dim, self.q, self.n
            )));
        }
        if self.file_len != data_len || self.table_fnv != table_fnv {
            return Err(Error::Data(
                "data file does not match the index artifact (stale or swapped \
                 .amdat — rebuild or re-save the index)"
                    .into(),
            ));
        }
        Ok(())
    }

    /// Read and checksum-verify class `ci`'s rows (seek-based; used by
    /// the resident v5 load, which walks every extent once).
    pub(crate) fn read_class(&mut self, ci: usize) -> Result<Vec<f32>> {
        let Some(ext) = self.extents.get(ci).copied() else {
            return Err(Error::Data(format!("class {ci} out of range")));
        };
        if ext.rows == 0 {
            return Ok(Vec::new());
        }
        let len = ext.rows as usize * self.dim * 4;
        let mut bytes = vec![0u8; len];
        self.file.seek(SeekFrom::Start(ext.offset))?;
        self.file.read_exact(&mut bytes)?;
        verify_extent(ci, &bytes, ext.fnv)?;
        Ok(decode_f32(&bytes))
    }
}

fn verify_extent(ci: usize, bytes: &[u8], stored: u64) -> Result<()> {
    let mut h = Fnv::new();
    h.update(bytes);
    if h.value() != stored {
        return Err(Error::Data(format!(
            "class {ci} extent corrupt: checksum {:#x} != stored {stored:#x}",
            h.value()
        )));
    }
    Ok(())
}

fn decode_f32(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

/// Positional read — `pread(2)` through the std `FileExt`: no shared
/// cursor, so concurrent class fetches never race a seek.
#[cfg(unix)]
fn pread_exact(
    file: &std::fs::File,
    buf: &mut [u8],
    offset: u64,
) -> std::io::Result<()> {
    use std::os::unix::fs::FileExt;
    file.read_exact_at(buf, offset)
}

#[cfg(not(unix))]
fn pread_exact(
    _file: &std::fs::File,
    _buf: &mut [u8],
    _offset: u64,
) -> std::io::Result<()> {
    Err(std::io::Error::new(
        std::io::ErrorKind::Unsupported,
        "positional reads require a unix platform",
    ))
}

/// Bounded LRU of decoded hot class extents, keyed by class.
#[derive(Debug)]
struct ExtentCache {
    budget: u64,
    bytes: u64,
    stamp: u64,
    entries: HashMap<usize, (Arc<Vec<f32>>, u64)>,
}

impl ExtentCache {
    fn new(budget: u64) -> Self {
        ExtentCache { budget, bytes: 0, stamp: 0, entries: HashMap::new() }
    }

    fn get(&mut self, ci: usize) -> Option<Arc<Vec<f32>>> {
        self.stamp += 1;
        let stamp = self.stamp;
        self.entries.get_mut(&ci).map(|e| {
            e.1 = stamp;
            e.0.clone()
        })
    }

    /// Insert (or refresh) an extent, then evict least-recently-used
    /// entries until the budget holds.  The just-inserted extent is
    /// never evicted, so a single over-budget extent still serves its
    /// batch (outstanding `Arc` handles keep evicted data alive until
    /// their scans finish).  Returns the number of evictions.
    fn insert(&mut self, ci: usize, rows: Arc<Vec<f32>>) -> u64 {
        let added = (rows.len() * 4) as u64;
        self.stamp += 1;
        if let Some((old, _)) = self.entries.insert(ci, (rows, self.stamp)) {
            self.bytes = self.bytes.saturating_sub((old.len() * 4) as u64);
        }
        self.bytes += added;
        let mut evicted = 0u64;
        while self.bytes > self.budget && self.entries.len() > 1 {
            let victim = self
                .entries
                .iter()
                .filter(|(&k, _)| k != ci)
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(&k, _)| k);
            let Some(k) = victim else { break };
            if let Some((old, _)) = self.entries.remove(&k) {
                self.bytes = self.bytes.saturating_sub((old.len() * 4) as u64);
                evicted += 1;
            }
        }
        evicted
    }
}

/// Cumulative I/O and cache accounting, shared by every clone of the
/// store (one physical store, one set of counters), plus the poison
/// slot that records the first I/O or integrity failure.
#[derive(Debug, Default)]
struct Counters {
    bytes_read: AtomicU64,
    extent_reads: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache_evictions: AtomicU64,
    poisoned: Mutex<Option<String>>,
}

/// The disk-resident vector store: class extents in an `.amdat` file,
/// fetched by positional reads through a bounded LRU cache.
///
/// Cloning is cheap and shares the file handle, cache, and counters.
/// All reads verify the extent checksum; the first failure poisons the
/// store ([`Self::error`]) and subsequent accesses to the failed class
/// yield no rows — the serving layers convert that into a request
/// error, never a silently wrong answer.
#[derive(Debug, Clone)]
pub struct PagedStore {
    file: Arc<std::fs::File>,
    dim: usize,
    extents: Arc<Vec<Extent>>,
    /// `vid -> class` (mirrors the partition; kept here so row reads
    /// need no index back-reference).
    class_of: Arc<Vec<u32>>,
    /// `vid -> row index within its class extent` (members-list order).
    row_of: Arc<Vec<u32>>,
    /// Total exact f32 payload bytes on disk (`n * dim * 4`).
    data_bytes: u64,
    cache: Arc<Mutex<ExtentCache>>,
    counters: Arc<Counters>,
}

impl PagedStore {
    /// Wrap an opened data file as a paged store.  `assignments` is the
    /// index's `vid -> class` map; per-class extent row counts are
    /// validated against it.
    pub(crate) fn from_data_file(
        df: DataFile,
        assignments: &[u32],
        cache_bytes: u64,
    ) -> Result<Self> {
        if !cfg!(unix) {
            return Err(Error::Config(
                "store mode \"paged\" requires a unix platform (positional \
                 reads); use \"resident\""
                    .into(),
            ));
        }
        if assignments.len() != df.n {
            return Err(Error::Data(format!(
                "{} assignments for a data file of n = {}",
                assignments.len(),
                df.n
            )));
        }
        // row_of: cursor per class over vid order — exactly the
        // members-list order the writer laid rows out in
        let mut next = vec![0u64; df.q];
        let mut row_of = Vec::with_capacity(df.n);
        for &c in assignments {
            let Some(slot) = next.get_mut(c as usize) else {
                return Err(Error::Data(format!("assignment to class {c} >= q")));
            };
            row_of.push(*slot as u32);
            *slot += 1;
        }
        for (ci, (&have, ext)) in next.iter().zip(df.extents.iter()).enumerate() {
            if have != ext.rows {
                return Err(Error::Data(format!(
                    "class {ci}: {have} members but extent has {} rows",
                    ext.rows
                )));
            }
        }
        let data_bytes = (df.n * df.dim * 4) as u64;
        Ok(PagedStore {
            file: Arc::new(df.file),
            dim: df.dim,
            extents: Arc::new(df.extents),
            class_of: Arc::new(assignments.to_vec()),
            row_of: Arc::new(row_of),
            data_bytes,
            cache: Arc::new(Mutex::new(ExtentCache::new(cache_bytes))),
            counters: Arc::new(Counters::default()),
        })
    }

    /// Class `ci`'s member rows: a cache hit, or one sequential
    /// positional read (verified against the extent checksum).  The
    /// read runs outside the cache lock, so concurrent fetches of
    /// *different* classes overlap; concurrent fetches of the *same*
    /// class may duplicate I/O (counted honestly) but stay correct.
    pub fn class_rows(&self, ci: usize) -> ClassRows<'_> {
        let Some(ext) = self.extents.get(ci).copied() else {
            return ClassRows::Borrowed(&[]);
        };
        if ext.rows == 0 {
            return ClassRows::Borrowed(&[]);
        }
        let cached = { lock_unpoisoned(&self.cache).get(ci) };
        if let Some(rows) = cached {
            self.counters.cache_hits.fetch_add(1, Ordering::Relaxed);
            return ClassRows::Cached(rows);
        }
        self.counters.cache_misses.fetch_add(1, Ordering::Relaxed);
        match self.fetch(ci, ext) {
            Ok(rows) => {
                let rows = Arc::new(rows);
                let evicted =
                    { lock_unpoisoned(&self.cache).insert(ci, rows.clone()) };
                if evicted > 0 {
                    self.counters
                        .cache_evictions
                        .fetch_add(evicted, Ordering::Relaxed);
                }
                ClassRows::Cached(rows)
            }
            Err(e) => {
                self.poison(format!("class {ci}: {e}"));
                ClassRows::Unavailable
            }
        }
    }

    fn fetch(&self, ci: usize, ext: Extent) -> Result<Vec<f32>> {
        let len = ext.rows as usize * self.dim * 4;
        let mut bytes = vec![0u8; len];
        pread_exact(&self.file, &mut bytes, ext.offset)
            .map_err(|e| Error::Data(format!("extent read failed: {e}")))?;
        self.counters.bytes_read.fetch_add(len as u64, Ordering::Relaxed);
        self.counters.extent_reads.fetch_add(1, Ordering::Relaxed);
        verify_extent(ci, &bytes, ext.fnv)?;
        Ok(decode_f32(&bytes))
    }

    /// Run `f` over vector `vid`'s exact row (the rerank read path).
    /// Rows of one class share its cached extent, so reranking `r`
    /// survivors costs at most one fetch per distinct class.  Returns
    /// `None` when the store is poisoned or `vid` is out of range
    /// (which also poisons — it indicates a corrupt id map).
    pub fn with_row<R>(&self, vid: usize, f: impl FnOnce(&[f32]) -> R) -> Option<R> {
        let (Some(&ci), Some(&ri)) =
            (self.class_of.get(vid), self.row_of.get(vid))
        else {
            self.poison(format!("row read for out-of-range vid {vid}"));
            return None;
        };
        let rows = self.class_rows(ci as usize);
        let start = ri as usize * self.dim;
        let row = rows.get(start..start + self.dim)?;
        Some(f(row))
    }

    /// Vector dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Record the first failure; later failures keep the original.
    fn poison(&self, msg: String) {
        let mut slot = lock_unpoisoned(&self.counters.poisoned);
        if slot.is_none() {
            *slot = Some(msg);
        }
    }

    /// The first I/O or integrity failure this store hit, if any.
    pub fn error(&self) -> Option<String> {
        lock_unpoisoned(&self.counters.poisoned).clone()
    }

    /// Accounting snapshot (counters are relaxed atomics: the snapshot
    /// is coherent enough for telemetry, not a linearizable point).
    pub fn stats(&self) -> StoreStats {
        let (cached_bytes, budget) = {
            let c = lock_unpoisoned(&self.cache);
            (c.bytes, c.budget)
        };
        StoreStats {
            kind: "paged",
            bytes_resident: cached_bytes,
            bytes_disk: self.data_bytes,
            bytes_read: self.counters.bytes_read.load(Ordering::Relaxed),
            extent_reads: self.counters.extent_reads.load(Ordering::Relaxed),
            cache_hits: self.counters.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.counters.cache_misses.load(Ordering::Relaxed),
            cache_evictions: self
                .counters
                .cache_evictions
                .load(Ordering::Relaxed),
            cache_budget: budget,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!(
            "amsearch_store_{}_{}",
            std::process::id(),
            name
        ))
    }

    /// A small partitioned dataset: n vectors of dim d over q classes,
    /// round-robin assignments.
    fn fixture(seed: u64, d: usize, n: usize, q: usize) -> (Dataset, Partition) {
        let mut rng = Rng::new(seed);
        let flat: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let data = Dataset::from_flat(d, flat).unwrap();
        let assignments: Vec<u32> = (0..n).map(|i| (i % q) as u32).collect();
        let partition = Partition::from_assignments(assignments, q).unwrap();
        (data, partition)
    }

    fn write_fixture(
        name: &str,
        seed: u64,
        d: usize,
        n: usize,
        q: usize,
    ) -> (std::path::PathBuf, Dataset, Partition, u64, u64) {
        let (data, partition) = fixture(seed, d, n, q);
        let path = tmp(name);
        let (len, fnv) = write_data_file(&path, &data, &partition).unwrap();
        (path, data, partition, len, fnv)
    }

    #[test]
    fn write_then_open_roundtrips_geometry_and_rows() {
        let (path, data, partition, len, fnv) =
            write_fixture("rt.amdat", 1, 8, 50, 4);
        let mut df = DataFile::open(&path).unwrap();
        assert_eq!((df.dim, df.q, df.n), (8, 4, 50));
        assert_eq!(df.file_len, len);
        assert_eq!(df.table_fnv, fnv);
        df.check_binding(8, 4, 50, len, fnv).unwrap();
        assert!(df.check_binding(8, 4, 50, len + 1, fnv).is_err());
        assert!(df.check_binding(8, 4, 49, len, fnv).is_err());
        // every extent is aligned and holds the class rows in
        // members-list order
        for ci in 0..4 {
            assert_eq!(df.extents[ci].offset % DATA_ALIGN, 0);
            let rows = df.read_class(ci).unwrap();
            let members = partition.members(ci);
            assert_eq!(rows.len(), members.len() * 8);
            for (i, &vid) in members.iter().enumerate() {
                assert_eq!(&rows[i * 8..(i + 1) * 8], data.get(vid as usize));
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_classes_get_zero_row_extents() {
        let d = 4;
        let data =
            Dataset::from_flat(d, vec![1.0; 2 * d]).unwrap();
        // classes 0 and 2 empty
        let partition = Partition::from_assignments(vec![1, 3], 4).unwrap();
        let path = tmp("empty.amdat");
        write_data_file(&path, &data, &partition).unwrap();
        let mut df = DataFile::open(&path).unwrap();
        assert_eq!(df.extents[0].rows, 0);
        assert!(df.read_class(0).unwrap().is_empty());
        assert_eq!(df.read_class(1).unwrap(), vec![1.0; d]);
        std::fs::remove_file(&path).ok();
    }

    #[cfg(unix)]
    #[test]
    fn paged_store_serves_rows_and_accounts_io() {
        let (path, data, partition, _, _) =
            write_fixture("paged.amdat", 2, 8, 60, 3);
        let assignments: Vec<u32> =
            (0..60).map(|i| partition.class_of(i)).collect();
        let df = DataFile::open(&path).unwrap();
        let store =
            PagedStore::from_data_file(df, &assignments, 1 << 20).unwrap();
        // first access: a miss and one sequential read of the extent
        let rows = store.class_rows(0);
        let members = partition.members(0);
        assert_eq!(rows.len(), members.len() * 8);
        for (i, &vid) in members.iter().enumerate() {
            assert_eq!(&rows[i * 8..(i + 1) * 8], data.get(vid as usize));
        }
        let s = store.stats();
        assert_eq!(s.kind, "paged");
        assert_eq!(s.extent_reads, 1);
        assert_eq!(s.cache_misses, 1);
        assert_eq!(s.cache_hits, 0);
        assert_eq!(s.bytes_read, (members.len() * 8 * 4) as u64);
        assert_eq!(s.bytes_disk, 60 * 8 * 4);
        // second access: pure cache hit, no new I/O
        drop(rows);
        let _rows = store.class_rows(0);
        let s2 = store.stats();
        assert_eq!(s2.extent_reads, 1);
        assert_eq!(s2.cache_hits, 1);
        assert_eq!(s2.bytes_read, s.bytes_read);
        // row reads agree with the dataset and ride the same cache
        for vid in [0usize, 7, 59] {
            let got = store.with_row(vid, |r| r.to_vec()).unwrap();
            assert_eq!(got.as_slice(), data.get(vid));
        }
        assert!(store.with_row(60, |r| r.to_vec()).is_none());
        assert!(store.error().is_some(), "out-of-range vid poisons");
        std::fs::remove_file(&path).ok();
    }

    #[cfg(unix)]
    #[test]
    fn tiny_budget_evicts_lru_but_keeps_serving() {
        let (path, data, partition, _, _) =
            write_fixture("evict.amdat", 3, 16, 90, 3);
        let assignments: Vec<u32> =
            (0..90).map(|i| partition.class_of(i)).collect();
        let df = DataFile::open(&path).unwrap();
        // budget below one extent (30 rows * 16 * 4 = 1920 bytes)
        let store = PagedStore::from_data_file(df, &assignments, 1024).unwrap();
        for round in 0..2 {
            for ci in 0..3 {
                let rows = store.class_rows(ci);
                let members = partition.members(ci);
                assert_eq!(rows.len(), members.len() * 16, "round {round}");
                for (i, &vid) in members.iter().enumerate() {
                    assert_eq!(
                        &rows[i * 16..(i + 1) * 16],
                        data.get(vid as usize)
                    );
                }
            }
        }
        let s = store.stats();
        // nothing fits next to anything else: every access is a miss
        assert_eq!(s.cache_misses, 6);
        assert_eq!(s.extent_reads, 6);
        assert!(s.cache_evictions >= 5, "evictions = {}", s.cache_evictions);
        assert!(s.bytes_resident <= 1920, "one extent at most stays cached");
        assert!(store.error().is_none());
        std::fs::remove_file(&path).ok();
    }

    #[cfg(unix)]
    #[test]
    fn corrupt_extent_poisons_instead_of_wrong_rows() {
        let (path, _, partition, _, _) =
            write_fixture("corrupt.amdat", 4, 8, 40, 2);
        let assignments: Vec<u32> =
            (0..40).map(|i| partition.class_of(i)).collect();
        // flip one payload byte in extent 0
        let df = DataFile::open(&path).unwrap();
        let off = df.extents[0].offset as usize;
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[off] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let df = DataFile::open(&path).unwrap();
        let store =
            PagedStore::from_data_file(df, &assignments, 1 << 20).unwrap();
        let rows = store.class_rows(0);
        assert!(rows.is_empty(), "corrupt extent yields no rows");
        let err = store.error().unwrap();
        assert!(err.contains("corrupt"), "{err}");
        assert!(store.with_row(0, |_| ()).is_none());
        // other extents still verify and serve
        assert!(!store.class_rows(1).is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn table_corruption_rejected_at_open() {
        let (path, _, _, _, _) = write_fixture("table.amdat", 5, 4, 20, 2);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[30] ^= 0x01; // inside the extent table
        std::fs::write(&path, &bytes).unwrap();
        let err = DataFile::open(&path).unwrap_err();
        assert!(err.to_string().contains("corrupt"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wrong_magic_and_truncation_rejected() {
        let path = tmp("magic.amdat");
        std::fs::write(&path, b"NOTADATAFILE....").unwrap();
        assert!(DataFile::open(&path).is_err());
        std::fs::remove_file(&path).ok();
        let (path, _, _, _, _) = write_fixture("trunc.amdat", 6, 4, 30, 2);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..40]).unwrap();
        assert!(DataFile::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[cfg(unix)]
    #[test]
    fn mismatched_assignments_rejected() {
        let (path, _, partition, _, _) =
            write_fixture("mismatch.amdat", 7, 4, 24, 3);
        let mut assignments: Vec<u32> =
            (0..24).map(|i| partition.class_of(i)).collect();
        assignments[0] = (assignments[0] + 1) % 3; // row counts now off
        let df = DataFile::open(&path).unwrap();
        assert!(PagedStore::from_data_file(df, &assignments, 1024).is_err());
        std::fs::remove_file(&path).ok();
    }
}
