//! Vector storage behind the candidate scan: where the exact f32
//! member matrices live.
//!
//! The paper's associative-memory poll prunes which *classes* get
//! exhaustively scanned.  With everything in RAM that pruning only
//! saves compute; this module turns it into an **I/O pruning** (the
//! "On Storage" ANN idea): the small hot state — AM super-memories,
//! quantized codes, codebooks — stays memory-resident, while the exact
//! f32 member matrices can live in a class-extent data file
//! (`*.amdat`, see [`paged`] and `docs/STORE_FORMAT.md`) and are read
//! on demand, one sequential `pread` per polled class.
//!
//! Two implementations behind one seam ([`Store`]):
//!
//! - [`Store::Resident`] — class-contiguous member slabs in RAM (the
//!   historical layout, bit-for-bit the previous behavior);
//! - [`Store::Paged`] — extents on disk, fetched through a bounded
//!   LRU cache of hot class extents with bytes-read / cache-hit
//!   accounting ([`PagedStore`]).
//!
//! The scan paths stay **infallible**: a read or checksum failure
//! poisons the paged store ([`PagedStore::error`]) and the affected
//! class yields no candidates; the `Result`-bearing serving layers
//! check the poison slot after the scan and fail the request, so a
//! wrong answer can never escape silently.
//!
//! Mode selection ([`StoreMode`]) threads from config/CLI through
//! [`StoreOptions`]; the paged full-rerank path is bitwise-equal to
//! the resident exact scan (same bytes, same kernels, same total
//! `(distance, id)` selection order — see the e2e suite).

mod paged;

pub use paged::PagedStore;
pub(crate) use paged::{write_data_file, DataFile, DATA_MAGIC};

use std::sync::Arc;

use crate::data::dataset::Dataset;
use crate::error::{Error, Result};

/// Incremental FNV-1a 64 (integrity checksum; not cryptographic).
/// Shared by the index artifact writer/reader ([`crate::index::persist`])
/// and the paged data file's per-extent checksums.
#[derive(Debug, Clone)]
pub(crate) struct Fnv(u64);

impl Fnv {
    pub(crate) fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }
    pub(crate) fn update(&mut self, data: &[u8]) {
        for &b in data {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    pub(crate) fn value(&self) -> u64 {
        self.0
    }
}

/// Where the exact f32 member matrices live.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StoreMode {
    /// Member matrices resident in RAM (the historical layout).
    #[default]
    Resident,
    /// Member matrices in a class-extent data file, paged in on demand.
    Paged,
}

impl StoreMode {
    /// Parse a config/CLI value ("resident" | "paged").
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "resident" => Ok(StoreMode::Resident),
            "paged" => Ok(StoreMode::Paged),
            other => Err(Error::Config(format!(
                "unknown store mode {other:?} (expected \"resident\" or \"paged\")"
            ))),
        }
    }

    /// The config/CLI name of this mode.
    pub fn name(&self) -> &'static str {
        match self {
            StoreMode::Resident => "resident",
            StoreMode::Paged => "paged",
        }
    }
}

/// How to open an index's vector store (config/CLI surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StoreOptions {
    /// Resident or paged.
    pub mode: StoreMode,
    /// Extent-cache budget for the paged store, in bytes.  Extents are
    /// evicted least-recently-used once the cached bytes exceed this.
    pub cache_bytes: u64,
}

/// Default extent-cache budget: 64 MiB — a few hot classes of a
/// billion-scale shard, small against the data file it fronts.
pub const DEFAULT_CACHE_BYTES: u64 = 64 * 1024 * 1024;

impl Default for StoreOptions {
    fn default() -> Self {
        StoreOptions { mode: StoreMode::Resident, cache_bytes: DEFAULT_CACHE_BYTES }
    }
}

/// One snapshot of a store's accounting, the substrate of the STATS
/// `store` object and the `amsearch_store_*` Prometheus families.
/// Counters are cumulative since open; byte gauges are current.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// "resident" | "paged".
    pub kind: &'static str,
    /// Exact f32 payload bytes held in RAM *right now* — the full
    /// member matrices for a resident store, the currently cached
    /// extents for a paged one.
    pub bytes_resident: u64,
    /// Exact f32 payload bytes on disk (0 for a resident store).
    pub bytes_disk: u64,
    /// Cumulative bytes fetched from disk (0 for a resident store).
    /// The headline I/O-pruning figure: at default fan-out this stays
    /// far below what a resident store keeps in RAM.
    pub bytes_read: u64,
    /// Cumulative extent fetches from disk.
    pub extent_reads: u64,
    /// Extent-cache hits.
    pub cache_hits: u64,
    /// Extent-cache misses (each miss implies one disk fetch).
    pub cache_misses: u64,
    /// Extents evicted to stay under the cache budget.
    pub cache_evictions: u64,
    /// The configured extent-cache budget in bytes.
    pub cache_budget: u64,
}

/// A class's member rows (flat `[rows × d]`, members-list order),
/// however the store produced them.  Derefs to `&[f32]`; an
/// [`ClassRows::Unavailable`] result (poisoned paged store) derefs to
/// an empty slice, so scan loops simply see zero candidates.
pub enum ClassRows<'a> {
    /// Borrowed straight from a resident slab.
    Borrowed(&'a [f32]),
    /// A shared handle into the paged extent cache.
    Cached(Arc<Vec<f32>>),
    /// The paged store failed to produce this extent (see
    /// [`PagedStore::error`]).
    Unavailable,
}

impl std::ops::Deref for ClassRows<'_> {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        match self {
            ClassRows::Borrowed(s) => s,
            ClassRows::Cached(a) => a.as_slice(),
            ClassRows::Unavailable => &[],
        }
    }
}

/// The vector store seam: one of the two layouts behind every exact
/// member-row access the index makes.
#[derive(Debug, Clone)]
pub enum Store {
    /// Class-contiguous member slabs in RAM: `slabs[ci]` holds class
    /// `ci`'s member rows in members-list order (empty for quantized
    /// indices, whose scan streams code rows and reranks through the
    /// dataset instead).
    Resident { slabs: Vec<Vec<f32>> },
    /// Class extents on disk behind a bounded LRU cache.
    Paged(PagedStore),
}

impl Store {
    /// Wrap resident slabs.
    pub fn resident(slabs: Vec<Vec<f32>>) -> Self {
        Store::Resident { slabs }
    }

    /// "resident" | "paged" — the STATS `store.kind` field.
    pub fn kind(&self) -> &'static str {
        match self {
            Store::Resident { .. } => "resident",
            Store::Paged(_) => "paged",
        }
    }

    /// True when member matrices are paged from disk.
    pub fn is_paged(&self) -> bool {
        matches!(self, Store::Paged(_))
    }

    /// Class `ci`'s member rows.  Resident: a borrow of the slab.
    /// Paged: a cache hit or one sequential extent read — called once
    /// per polled class per *batch* by the class-major scan, which is
    /// exactly the read coalescing the paged layout is built around.
    pub fn class_rows(&self, ci: usize) -> ClassRows<'_> {
        match self {
            Store::Resident { slabs } => match slabs.get(ci) {
                Some(slab) => ClassRows::Borrowed(slab),
                None => ClassRows::Borrowed(&[]),
            },
            Store::Paged(p) => p.class_rows(ci),
        }
    }

    /// The first error the paged store hit, if any (`None` for
    /// resident stores and healthy paged ones).  Serving layers check
    /// this after a scan to turn silent zero-candidate classes into a
    /// failed request.
    pub fn error(&self) -> Option<String> {
        match self {
            Store::Resident { .. } => None,
            Store::Paged(p) => p.error(),
        }
    }
}

/// Row-granular exact reads for the rerank stage, however the vectors
/// are stored.  The resident variant borrows the dataset; the paged
/// variant routes through the extent cache (survivors of one class
/// share its single fetch).
pub enum RowReader<'a> {
    /// Rows come from the resident dataset.
    Dataset(&'a Dataset),
    /// Rows come from paged class extents.
    Paged(&'a PagedStore),
}

impl RowReader<'_> {
    /// Run `f` over vector `vid`'s exact f32 row.  Returns `None` only
    /// when a paged store failed to produce the row (poisoned; see
    /// [`PagedStore::error`]) — the caller then skips the candidate
    /// and the serving layer surfaces the stored error.
    pub fn with_row<R>(&self, vid: usize, f: impl FnOnce(&[f32]) -> R) -> Option<R> {
        match self {
            RowReader::Dataset(d) => Some(f(d.get(vid))),
            RowReader::Paged(p) => p.with_row(vid, f),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_mode_parses_and_rejects() {
        assert_eq!(StoreMode::parse("resident").unwrap(), StoreMode::Resident);
        assert_eq!(StoreMode::parse("paged").unwrap(), StoreMode::Paged);
        assert!(StoreMode::parse("mmap").is_err());
        assert_eq!(StoreMode::Paged.name(), "paged");
        assert_eq!(StoreMode::default(), StoreMode::Resident);
    }

    #[test]
    fn resident_store_serves_slabs_and_never_errors() {
        let store =
            Store::resident(vec![vec![1.0, 2.0], Vec::new(), vec![3.0, 4.0]]);
        assert_eq!(store.kind(), "resident");
        assert!(!store.is_paged());
        assert_eq!(&*store.class_rows(0), &[1.0, 2.0][..]);
        assert!(store.class_rows(1).is_empty());
        assert_eq!(&*store.class_rows(2), &[3.0, 4.0][..]);
        // out-of-range class degrades to empty, like an empty class
        assert!(store.class_rows(9).is_empty());
        assert!(store.error().is_none());
    }

    #[test]
    fn row_reader_over_dataset() {
        let ds = Dataset::from_flat(2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let rows = RowReader::Dataset(&ds);
        let got = rows.with_row(1, |r| r.to_vec());
        assert_eq!(got, Some(vec![3.0, 4.0]));
    }

    #[test]
    fn fnv_matches_reference_vectors() {
        // FNV-1a 64 of the empty string is the offset basis; "a" is the
        // published reference value
        assert_eq!(Fnv::new().value(), 0xcbf2_9ce4_8422_2325);
        let mut h = Fnv::new();
        h.update(b"a");
        assert_eq!(h.value(), 0xaf63_dc4c_8601_ec8c);
    }
}
