//! Per-figure experiment drivers: one function per figure of the paper's
//! evaluation section (§5), each regenerating the figure's data series.
//!
//! Parameters mirror the paper exactly where feasible; the real datasets
//! of Figures 9–12 are replaced by the surrogates documented in
//! DESIGN.md §6, and the default database sizes are scaled down so the
//! full suite runs in CI time.  `EvalOptions::scale` restores
//! paper-scale Monte-Carlo counts and collection sizes.

use std::sync::Arc;

use crate::baseline::{Exhaustive, HybridIndex, RsAnchors};
use crate::data::clustered::{self, ClusteredSpec};
use crate::data::dataset::{Dataset, Workload};
use crate::data::rng::Rng;
use crate::data::{mnist_like, santander_like};
use crate::error::Result;
use crate::index::{AmIndex, IndexParams};
use crate::memory::StorageRule;
use crate::metrics::{OpsCounter, Recall};
use crate::partition::Allocation;
use crate::search::Metric;
use crate::util::par::{parallel_map, parallel_map_items};

use super::report::{Figure, Series};
use super::runner::{class_selection_trials, PatternModel, TrialConfig};

/// Global evaluation options.
#[derive(Debug, Clone, Copy)]
pub struct EvalOptions {
    /// Multiplier on Monte-Carlo trial counts and dataset sizes
    /// (1.0 = CI defaults; ~10 approaches paper scale).
    pub scale: f64,
    /// Base RNG seed.
    pub seed: u64,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions { scale: 1.0, seed: 42 }
    }
}

impl EvalOptions {
    fn trials(&self, base: usize) -> usize {
        ((base as f64) * self.scale).round().max(50.0) as usize
    }

    fn size(&self, base: usize) -> usize {
        ((base as f64) * self.scale).round().max(100.0) as usize
    }
}

// ---------------------------------------------------------------------
// Figures 1-8: synthetic error-rate curves
// ---------------------------------------------------------------------

fn error_curve(
    label: &str,
    xs: impl IntoIterator<Item = (f64, TrialConfig)>,
    trials: usize,
    seed: u64,
) -> Series {
    let configs: Vec<(f64, TrialConfig)> = xs.into_iter().collect();
    let mut series = Series::new(label);
    let results: Vec<(f64, Recall)> = parallel_map_items(&configs, |(x, cfg)| {
        let dbs = (trials / 2000).clamp(2, 16);
        (*x, class_selection_trials(*cfg, trials, dbs, seed ^ (*x as u64)))
    });
    for (x, r) in results {
        series.push_aux(x, r.error_rate(), r.std_error());
    }
    series
}

/// Figure 1: sparse, error rate vs k (q=10, d=128, c=8).
pub fn fig1(opts: &EvalOptions) -> Figure {
    let mut fig = Figure::new(
        "fig1",
        "Error rate vs k (sparse; q=10, d=128, c=8)",
        "k",
        "error_rate",
    );
    let trials = opts.trials(10_000);
    let base = TrialConfig {
        d: 128,
        k: 0,
        q: 10,
        model: PatternModel::Sparse { ones: 8.0 },
        alpha: None,
        rule: StorageRule::Sum,
    };
    let ks = [16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384];
    fig.series.push(error_curve(
        "q=10",
        ks.iter().map(|&k| (k as f64, TrialConfig { k, ..base })),
        trials,
        opts.seed,
    ));
    fig
}

/// Figure 2: sparse, error rate vs q for several k (d=128, c=8).
pub fn fig2(opts: &EvalOptions) -> Figure {
    let mut fig = Figure::new(
        "fig2",
        "Error rate vs q (sparse; d=128, c=8)",
        "q",
        "error_rate",
    );
    let trials = opts.trials(5_000);
    let base = TrialConfig {
        d: 128,
        k: 0,
        q: 0,
        model: PatternModel::Sparse { ones: 8.0 },
        alpha: None,
        rule: StorageRule::Sum,
    };
    let qs = [2, 5, 10, 20, 50, 100];
    for &k in &[128usize, 512, 2048, 8192] {
        fig.series.push(error_curve(
            &format!("k={k}"),
            qs.iter().map(|&q| (q as f64, TrialConfig { k, q, ..base })),
            trials,
            opts.seed + k as u64,
        ));
    }
    fig
}

/// Figure 3: sparse, error rate vs k at fixed n = k·q = 16384
/// (d=128, c=8).
pub fn fig3(opts: &EvalOptions) -> Figure {
    let mut fig = Figure::new(
        "fig3",
        "Error rate vs k at fixed n=16384 (sparse; d=128, c=8)",
        "k",
        "error_rate",
    );
    let trials = opts.trials(10_000);
    let n = 16384usize;
    let base = TrialConfig {
        d: 128,
        k: 0,
        q: 0,
        model: PatternModel::Sparse { ones: 8.0 },
        alpha: None,
        rule: StorageRule::Sum,
    };
    let ks = [64, 128, 256, 512, 1024, 2048, 4096, 8192];
    fig.series.push(error_curve(
        "n=16384",
        ks.iter().map(|&k| {
            (k as f64, TrialConfig { k, q: n / k, ..base })
        }),
        trials,
        opts.seed,
    ));
    fig
}

/// Figure 4: sparse, error rate vs d (q=2, c=log2(d), k=d^α/10).
pub fn fig4(opts: &EvalOptions) -> Figure {
    let mut fig = Figure::new(
        "fig4",
        "Error rate vs d (sparse; q=2, c=log2(d), k=d^a/10)",
        "d",
        "error_rate",
    );
    let trials = opts.trials(5_000);
    for &(alpha, label) in
        &[(1.5f64, "alpha=1.5"), (2.0, "alpha=2.0"), (2.5, "alpha=2.5")]
    {
        let ds: &[usize] = if alpha > 2.2 {
            &[32, 64, 128, 256]
        } else {
            &[32, 64, 128, 256, 512]
        };
        let cfgs = ds.iter().map(|&d| {
            let k = (((d as f64).powf(alpha)) / 10.0).round().max(2.0) as usize;
            (
                d as f64,
                TrialConfig {
                    d,
                    k,
                    q: 2,
                    model: PatternModel::Sparse { ones: (d as f64).log2() },
                    alpha: None,
                    rule: StorageRule::Sum,
                },
            )
        });
        fig.series.push(error_curve(label, cfgs, trials, opts.seed + alpha as u64));
    }
    fig
}

/// Figure 5: dense, error rate vs k (q=10, d=64).
pub fn fig5(opts: &EvalOptions) -> Figure {
    let mut fig = Figure::new(
        "fig5",
        "Error rate vs k (dense; q=10, d=64)",
        "k",
        "error_rate",
    );
    let trials = opts.trials(2_000);
    let base = TrialConfig {
        d: 64,
        k: 0,
        q: 10,
        model: PatternModel::Dense,
        alpha: None,
        rule: StorageRule::Sum,
    };
    let ks = [16, 32, 64, 128, 256, 512, 1024, 2048, 4096];
    fig.series.push(error_curve(
        "q=10",
        ks.iter().map(|&k| (k as f64, TrialConfig { k, ..base })),
        trials,
        opts.seed,
    ));
    fig
}

/// Figure 6: dense, error rate vs q for several k (d=64).
pub fn fig6(opts: &EvalOptions) -> Figure {
    let mut fig = Figure::new(
        "fig6",
        "Error rate vs q (dense; d=64)",
        "q",
        "error_rate",
    );
    let trials = opts.trials(2_000);
    let base = TrialConfig {
        d: 64,
        k: 0,
        q: 0,
        model: PatternModel::Dense,
        alpha: None,
        rule: StorageRule::Sum,
    };
    let qs = [2, 5, 10, 20, 50];
    for &k in &[64usize, 256, 1024, 4096] {
        fig.series.push(error_curve(
            &format!("k={k}"),
            qs.iter().map(|&q| (q as f64, TrialConfig { k, q, ..base })),
            trials,
            opts.seed + k as u64,
        ));
    }
    fig
}

/// Figure 7: dense, error rate vs k at fixed n=16384 (d=64).
pub fn fig7(opts: &EvalOptions) -> Figure {
    let mut fig = Figure::new(
        "fig7",
        "Error rate vs k at fixed n=16384 (dense; d=64)",
        "k",
        "error_rate",
    );
    let trials = opts.trials(2_000);
    let n = 16384usize;
    let base = TrialConfig {
        d: 64,
        k: 0,
        q: 0,
        model: PatternModel::Dense,
        alpha: None,
        rule: StorageRule::Sum,
    };
    let ks = [64, 128, 256, 512, 1024, 2048, 4096, 8192];
    fig.series.push(error_curve(
        "n=16384",
        ks.iter().map(|&k| (k as f64, TrialConfig { k, q: n / k, ..base })),
        trials,
        opts.seed,
    ));
    fig
}

/// Figure 8: dense, error rate vs d (q=2, k=d^α).
pub fn fig8(opts: &EvalOptions) -> Figure {
    let mut fig = Figure::new(
        "fig8",
        "Error rate vs d (dense; q=2, k=d^a)",
        "d",
        "error_rate",
    );
    let trials = opts.trials(2_000);
    for &(alpha, label) in
        &[(1.5f64, "alpha=1.5"), (2.0, "alpha=2.0"), (2.5, "alpha=2.5")]
    {
        let ds: &[usize] = if alpha > 2.2 {
            &[16, 24, 32, 48, 64]
        } else {
            &[16, 24, 32, 48, 64, 96, 128]
        };
        let cfgs = ds.iter().map(|&d| {
            let k = ((d as f64).powf(alpha)).round().max(2.0) as usize;
            (
                d as f64,
                TrialConfig {
                    d,
                    k,
                    q: 2,
                    model: PatternModel::Dense,
                    alpha: None,
                    rule: StorageRule::Sum,
                },
            )
        });
        fig.series.push(error_curve(label, cfgs, trials, opts.seed + alpha as u64));
    }
    fig
}

// ---------------------------------------------------------------------
// Figures 9-12: recall@1 vs relative complexity on real-data surrogates
// ---------------------------------------------------------------------

/// Sweep poll depth p and emit (relative complexity, recall@1) points for
/// an AM index on a workload.
///
/// The class ranking is independent of p, so each query is processed
/// once: classes are scanned in rank order and (hit, cumulative-ops) are
/// recorded at every p in the sweep — a |p_sweep|-fold saving that makes
/// the paper-scale figures tractable on one core.
fn am_tradeoff_curve(
    label: &str,
    wl: &Workload,
    params: IndexParams,
    p_sweep: &[usize],
    seed: u64,
) -> Result<Series> {
    let mut rng = Rng::new(seed);
    let index = Arc::new(AmIndex::build(wl.base.clone(), params, &mut rng)?);
    let reference = Exhaustive::new(wl.base.clone(), params.metric);
    let ps: Vec<usize> =
        p_sweep.iter().cloned().filter(|&p| p <= params.n_classes).collect();
    // per query: (hit@p, ops@p) for every p in ps, plus the reference cost
    let per_query: Vec<(Vec<(bool, u64)>, u64)> =
        parallel_map(wl.queries.len(), |qi| {
            let x = wl.queries.get(qi);
            let mut ops = OpsCounter::new();
            let ranked = index.ranked_classes(x, &mut ops);
            let score_ops = ops.score_ops;
            let per_cand = if index.uses_sparse_scoring() {
                x.iter().filter(|&&v| v != 0.0).count()
            } else {
                index.dim()
            } as u64;
            let metric = params.metric;
            let mut best = f32::INFINITY;
            let mut best_id = u32::MAX;
            let mut scanned = 0u64;
            let mut out = Vec::with_capacity(ps.len());
            let mut next_p = 0usize;
            for (rank, &ci) in ranked.iter().enumerate() {
                for &vid in index.partition().members(ci as usize) {
                    let dist = metric.distance(x, index.data().get(vid as usize));
                    scanned += 1;
                    if dist < best || (dist == best && vid < best_id) {
                        best = dist;
                        best_id = vid;
                    }
                }
                while next_p < ps.len() && ps[next_p] == rank + 1 {
                    out.push((
                        best_id == wl.ground_truth[qi],
                        score_ops + scanned * per_cand,
                    ));
                    next_p += 1;
                }
                if next_p == ps.len() {
                    break;
                }
            }
            (out, reference.reference_ops(x))
        });
    let mut series = Series::new(label);
    for (pi, _p) in ps.iter().enumerate() {
        let mut recall = Recall::new();
        let mut total_ops = 0u64;
        let mut total_ref = 0u64;
        for (rows, reference_ops) in &per_query {
            recall.record(rows[pi].0);
            total_ops += rows[pi].1;
            total_ref += reference_ops;
        }
        let rel = total_ops as f64 / total_ref.max(1) as f64;
        series.push_aux(rel, recall.value(), recall.std_error());
    }
    Ok(series)
}

/// Same trade-off sweep for the RS baseline (p = anchors polled).
fn rs_tradeoff_curve(
    label: &str,
    wl: &Workload,
    r: usize,
    p_sweep: &[usize],
    metric: Metric,
    seed: u64,
) -> Result<Series> {
    let mut rng = Rng::new(seed);
    let r = r.min(wl.base.len()); // scaled-down runs clamp the anchor count
    let rs = RsAnchors::build(wl.base.clone(), r, metric, &mut rng)?;
    let reference = Exhaustive::new(wl.base.clone(), metric);
    let ps: Vec<usize> = p_sweep.iter().cloned().filter(|&p| p <= r).collect();
    // one pass per query: rank anchors once, scan attachments in rank
    // order, snapshot (hit, cumulative ops) at every p in the sweep
    let per_query: Vec<(Vec<(bool, u64)>, u64)> =
        parallel_map(wl.queries.len(), |qi| {
            let x = wl.queries.get(qi);
            let mut ops = OpsCounter::new();
            let ranked = rs.ranked_anchors(x, &mut ops);
            let anchor_ops = ops.aux_ops;
            let per_cand = rs.per_candidate(x) as u64;
            let metric = rs.metric();
            let mut best = f32::INFINITY;
            let mut best_id = u32::MAX;
            let mut scanned = 0u64;
            let mut rows = Vec::with_capacity(ps.len());
            let mut next_p = 0usize;
            for (rank, &a) in ranked.iter().enumerate() {
                for &vid in rs.attached(a as usize) {
                    let dist = metric.distance(x, rs.vector(vid));
                    scanned += 1;
                    if dist < best || (dist == best && vid < best_id) {
                        best = dist;
                        best_id = vid;
                    }
                }
                while next_p < ps.len() && ps[next_p] == rank + 1 {
                    rows.push((
                        best_id == wl.ground_truth[qi],
                        anchor_ops + scanned * per_cand,
                    ));
                    next_p += 1;
                }
                if next_p == ps.len() {
                    break;
                }
            }
            (rows, reference.reference_ops(x))
        });
    let mut series = Series::new(label);
    for (pi, _p) in ps.iter().enumerate() {
        let mut recall = Recall::new();
        let mut total_ops = 0u64;
        let mut total_ref = 0u64;
        for (rows, reference_ops) in &per_query {
            recall.record(rows[pi].0);
            total_ops += rows[pi].1;
            total_ref += reference_ops;
        }
        series.push_aux(
            total_ops as f64 / total_ref.max(1) as f64,
            recall.value(),
            recall.std_error(),
        );
    }
    Ok(series)
}

/// Hybrid AM->RS trade-off sweep.
fn hybrid_tradeoff_curve(
    label: &str,
    wl: &Workload,
    params: IndexParams,
    anchors_per_class: usize,
    p_sweep: &[usize],
    seed: u64,
) -> Result<Series> {
    let mut rng = Rng::new(seed);
    let hy = HybridIndex::build(wl.base.clone(), params, 1.0, anchors_per_class, &mut rng)?;
    let reference = Exhaustive::new(wl.base.clone(), params.metric);
    let mut series = Series::new(label);
    for &p in p_sweep {
        if p > params.n_classes {
            continue;
        }
        let results: Vec<(bool, u64, u64)> = parallel_map(wl.queries.len(), |qi| {
            let x = wl.queries.get(qi);
            let mut ops = OpsCounter::new();
            let (id, _) = hy.query(x, p, &mut ops);
            (id == wl.ground_truth[qi], ops.total(), reference.reference_ops(x))
        });
        let mut recall = Recall::new();
        let mut total_ops = 0u64;
        let mut total_ref = 0u64;
        for (hit, ops, reference_ops) in results {
            recall.record(hit);
            total_ops += ops;
            total_ref += reference_ops;
        }
        series.push_aux(
            total_ops as f64 / total_ref.max(1) as f64,
            recall.value(),
            recall.std_error(),
        );
    }
    Ok(series)
}

fn p_sweep_for(q: usize) -> Vec<usize> {
    let mut ps = vec![1usize, 2, 4, 8, 16, 32, 64, 128];
    ps.retain(|&p| p <= q);
    if ps.last() != Some(&q) {
        ps.push(q);
    }
    ps
}

/// Figure 9: recall@1 vs relative complexity on the MNIST surrogate,
/// greedy vs random allocation vs RS.
pub fn fig9(opts: &EvalOptions) -> Result<Figure> {
    let mut fig = Figure::new(
        "fig9",
        "Recall@1 vs relative complexity (MNIST-like surrogate)",
        "relative_complexity",
        "recall_at_1",
    );
    let n = opts.size(3_000);
    let n_queries = opts.size(300);
    let mut rng = Rng::new(opts.seed);
    let mut wl = mnist_like::mnist_like_workload(n, n_queries, &mut rng);
    // paper §5.2 preprocessing for non-sparse data
    let mean = wl.base.center_and_normalize();
    let mut queries = Dataset::empty(wl.queries.dim());
    for qi in 0..wl.queries.len() {
        queries
            .push(&Dataset::preprocess_query(wl.queries.get(qi), &mean))
            .expect("dims");
    }
    wl.queries = queries;
    wl.ground_truth = clustered::exact_ground_truth(&wl.base, &wl.queries);

    for &k in &[200usize, 500, 1000] {
        let q = (n / k).max(2);
        let params = IndexParams {
            n_classes: q,
            allocation: Allocation::Greedy,
            greedy_cap_factor: Some(4.0),
            ..Default::default()
        };
        fig.series.push(am_tradeoff_curve(
            &format!("am_greedy_k={k}"),
            &wl,
            params,
            &p_sweep_for(q),
            opts.seed + k as u64,
        )?);
        let params = IndexParams {
            n_classes: q,
            allocation: Allocation::Random,
            ..Default::default()
        };
        fig.series.push(am_tradeoff_curve(
            &format!("am_random_k={k}"),
            &wl,
            params,
            &p_sweep_for(q),
            opts.seed + 7 * k as u64,
        )?);
    }
    for &r in &[20usize, 50, 100] {
        fig.series.push(rs_tradeoff_curve(
            &format!("rs_r={r}"),
            &wl,
            r,
            &p_sweep_for(r),
            Metric::SqL2,
            opts.seed + 13 * r as u64,
        )?);
    }
    Ok(fig)
}

/// Figure 10: recall@1 vs relative complexity on the Santander-like
/// sparse binary surrogate (queries = stored vectors).
pub fn fig10(opts: &EvalOptions) -> Result<Figure> {
    let mut fig = Figure::new(
        "fig10",
        "Recall@1 vs relative complexity (Santander-like surrogate)",
        "relative_complexity",
        "recall_at_1",
    );
    let n = opts.size(20_000);
    let n_queries = opts.size(1_000);
    let mut rng = Rng::new(opts.seed);
    let wl = santander_like::santander_like_workload(n, n_queries, &mut rng);
    for &k in &[250usize, 500, 1000] {
        let q = (n / k).max(2);
        let params = IndexParams {
            n_classes: q,
            allocation: Allocation::Greedy,
            greedy_cap_factor: Some(4.0),
            ..Default::default()
        };
        fig.series.push(am_tradeoff_curve(
            &format!("am_greedy_k={k}"),
            &wl,
            params,
            &p_sweep_for(q),
            opts.seed + k as u64,
        )?);
    }
    for &r in &[50usize, 140, 400] {
        fig.series.push(rs_tradeoff_curve(
            &format!("rs_r={r}"),
            &wl,
            r,
            &p_sweep_for(r),
            Metric::SqL2,
            opts.seed + 13 * r as u64,
        )?);
    }
    Ok(fig)
}

/// Figure 11: recall@1 vs relative complexity on the SIFT1M-like
/// surrogate, including the AM->RS hybrid.
pub fn fig11(opts: &EvalOptions) -> Result<Figure> {
    let mut fig = Figure::new(
        "fig11",
        "Recall@1 vs relative complexity (SIFT1M-like surrogate)",
        "relative_complexity",
        "recall_at_1",
    );
    let n = opts.size(100_000);
    let n_queries = opts.size(1_000);
    let mut rng = Rng::new(opts.seed);
    let wl = clustered::clustered_workload(ClusteredSpec::sift_like(), n, n_queries, &mut rng);

    for &k in &[500usize, 1000, 2000] {
        let q = (n / k).max(2);
        let params =
            IndexParams { n_classes: q, allocation: Allocation::Random, ..Default::default() };
        fig.series.push(am_tradeoff_curve(
            &format!("am_random_k={k}"),
            &wl,
            params,
            &p_sweep_for(q),
            opts.seed + k as u64,
        )?);
    }
    for &r in &[100usize, 316, 1000] {
        fig.series.push(rs_tradeoff_curve(
            &format!("rs_r={r}"),
            &wl,
            r,
            &p_sweep_for(r),
            Metric::SqL2,
            opts.seed + 13 * r as u64,
        )?);
    }
    // hybrid: AM (k=2000) classes searched with per-class RS anchors
    let q = (n / 2000).max(2);
    let params =
        IndexParams { n_classes: q, allocation: Allocation::Random, ..Default::default() };
    fig.series.push(hybrid_tradeoff_curve(
        "hybrid_am_rs_k=2000",
        &wl,
        params,
        4,
        &p_sweep_for(q),
        opts.seed + 999,
    )?);
    // modern-practice reference: IVF-flat (k-means coarse quantizer)
    fig.series.push(ivf_tradeoff_curve(
        "ivf_flat_r=316",
        &wl,
        316,
        &p_sweep_for(316),
        opts.seed + 1717,
    )?);
    Ok(fig)
}

/// IVF-flat trade-off sweep (same incremental structure as RS).
fn ivf_tradeoff_curve(
    label: &str,
    wl: &Workload,
    n_lists: usize,
    p_sweep: &[usize],
    seed: u64,
) -> Result<Series> {
    use crate::baseline::IvfFlat;
    let mut rng = Rng::new(seed);
    let n_lists = n_lists.min(wl.base.len());
    let ivf = IvfFlat::build(wl.base.clone(), n_lists, 10, Metric::SqL2, &mut rng)?;
    let reference = Exhaustive::new(wl.base.clone(), Metric::SqL2);
    let ps: Vec<usize> =
        p_sweep.iter().cloned().filter(|&p| p <= n_lists).collect();
    let per_query: Vec<(Vec<(bool, u64)>, u64)> =
        parallel_map(wl.queries.len(), |qi| {
            let x = wl.queries.get(qi);
            let mut rows = Vec::with_capacity(ps.len());
            for &p in &ps {
                let mut ops = OpsCounter::new();
                let (id, _, _) = ivf.query(x, p, &mut ops);
                rows.push((id == wl.ground_truth[qi], ops.total()));
            }
            (rows, reference.reference_ops(x))
        });
    let mut series = Series::new(label);
    for (pi, _p) in ps.iter().enumerate() {
        let mut recall = Recall::new();
        let mut total_ops = 0u64;
        let mut total_ref = 0u64;
        for (rows, reference_ops) in &per_query {
            recall.record(rows[pi].0);
            total_ops += rows[pi].1;
            total_ref += reference_ops;
        }
        series.push_aux(
            total_ops as f64 / total_ref.max(1) as f64,
            recall.value(),
            recall.std_error(),
        );
    }
    Ok(series)
}

/// Figure 12: recall@1 vs relative complexity on the GIST1M-like
/// surrogate (960-d).
pub fn fig12(opts: &EvalOptions) -> Result<Figure> {
    let mut fig = Figure::new(
        "fig12",
        "Recall@1 vs relative complexity (GIST1M-like surrogate)",
        "relative_complexity",
        "recall_at_1",
    );
    let n = opts.size(20_000);
    let n_queries = opts.size(500);
    let mut rng = Rng::new(opts.seed);
    let wl = clustered::clustered_workload(ClusteredSpec::gist_like(), n, n_queries, &mut rng);
    for &k in &[1000usize, 2000, 4000] {
        let q = (n / k).max(2);
        let params =
            IndexParams { n_classes: q, allocation: Allocation::Random, ..Default::default() };
        fig.series.push(am_tradeoff_curve(
            &format!("am_random_k={k}"),
            &wl,
            params,
            &p_sweep_for(q),
            opts.seed + k as u64,
        )?);
    }
    for &r in &[45usize, 141, 450] {
        fig.series.push(rs_tradeoff_curve(
            &format!("rs_r={r}"),
            &wl,
            r,
            &p_sweep_for(r),
            Metric::SqL2,
            opts.seed + 13 * r as u64,
        )?);
    }
    Ok(fig)
}

/// Ablation (§5.1.1 remark): sum rule vs max (cooccurrence) rule on the
/// Figure-1 sparse setup.
pub fn ablation_rule(opts: &EvalOptions) -> Figure {
    let mut fig = Figure::new(
        "ablation_rule",
        "Sum rule vs cooccurrence (max) rule (sparse; q=10, d=128, c=8)",
        "k",
        "error_rate",
    );
    let trials = opts.trials(5_000);
    let ks = [64usize, 256, 1024, 4096];
    for &(rule, label) in
        &[(StorageRule::Sum, "sum_rule"), (StorageRule::Max, "max_rule")]
    {
        let cfgs = ks.iter().map(|&k| {
            (
                k as f64,
                TrialConfig {
                    d: 128,
                    k,
                    q: 10,
                    model: PatternModel::Sparse { ones: 8.0 },
                    alpha: None,
                    rule,
                },
            )
        });
        fig.series.push(error_curve(label, cfgs, trials, opts.seed));
    }
    fig
}

/// Ablation (Cor 3.2/4.2): corrupted queries, error rate vs overlap α.
pub fn ablation_corruption(opts: &EvalOptions) -> Figure {
    let mut fig = Figure::new(
        "ablation_corruption",
        "Error rate vs query overlap alpha (Cor 3.2 / 4.2 regimes)",
        "alpha",
        "error_rate",
    );
    let trials = opts.trials(4_000);
    let alphas = [0.2f64, 0.4, 0.6, 0.8, 1.0];
    let sparse = TrialConfig {
        d: 128,
        k: 1024,
        q: 10,
        model: PatternModel::Sparse { ones: 8.0 },
        alpha: None,
        rule: StorageRule::Sum,
    };
    let cfgs = alphas.iter().map(|&a| {
        (a, TrialConfig { alpha: if a >= 1.0 { None } else { Some(a) }, ..sparse })
    });
    fig.series.push(error_curve("sparse_k=1024", cfgs, trials, opts.seed));
    let dense = TrialConfig {
        d: 64,
        k: 512,
        q: 10,
        model: PatternModel::Dense,
        alpha: None,
        rule: StorageRule::Sum,
    };
    let cfgs = alphas.iter().map(|&a| {
        (a, TrialConfig { alpha: if a >= 1.0 { None } else { Some(a) }, ..dense })
    });
    fig.series.push(error_curve("dense_k=512", cfgs, trials, opts.seed + 1));
    fig
}

/// Ablation (conclusion / future work): two-level hierarchical cascade vs
/// flat index — recall and scoring cost at matched scan budgets.
pub fn ablation_hierarchical(opts: &EvalOptions) -> Result<Figure> {
    use crate::index::HierarchicalIndex;
    let mut fig = Figure::new(
        "ablation_hierarchical",
        "Flat vs two-level cascade (dense d=64, n=16384, q=64)",
        "scoring_ops",
        "recall_at_1",
    );
    let n = opts.size(16_384);
    let n_queries = opts.trials(400).min(n);
    let mut rng = Rng::new(opts.seed);
    let wl = crate::data::synthetic::dense_workload(
        64,
        n,
        n_queries,
        crate::data::synthetic::QueryModel::Corrupted { alpha: 0.9 },
        &mut rng,
    );
    let q = 64.min(n / 4);
    let params = IndexParams { n_classes: q, ..Default::default() };

    // flat index at p = 1, 2, 4
    let flat = AmIndex::build(wl.base.clone(), params, &mut rng)?;
    let mut series = Series::new("flat");
    for p in [1usize, 2, 4] {
        let results: Vec<(bool, u64)> = parallel_map(wl.queries.len(), |qi| {
            let mut ops = OpsCounter::new();
            let r = flat.query(wl.queries.get(qi), p, &mut ops);
            (r.id() == wl.ground_truth[qi], ops.score_ops)
        });
        let mut recall = Recall::new();
        let mut score_ops = 0u64;
        for (hit, ops) in results {
            recall.record(hit);
            score_ops += ops;
        }
        series.push_aux(
            score_ops as f64 / wl.queries.len() as f64,
            recall.value(),
            recall.std_error(),
        );
    }
    fig.series.push(series);

    // cascade with s = 8 super-classes at p1 = 1, 2, 4 (p2 matched)
    let h = HierarchicalIndex::build(wl.base.clone(), params, 8.min(q), &mut rng)?;
    let mut series = Series::new("cascade_s=8");
    for (p1, p2) in [(1usize, 1usize), (2, 2), (4, 4)] {
        let results: Vec<(bool, u64)> = parallel_map(wl.queries.len(), |qi| {
            let mut ops = OpsCounter::new();
            let r = h.query(wl.queries.get(qi), p1, p2, &mut ops);
            (r.id() == wl.ground_truth[qi], ops.score_ops)
        });
        let mut recall = Recall::new();
        let mut score_ops = 0u64;
        for (hit, ops) in results {
            recall.record(hit);
            score_ops += ops;
        }
        series.push_aux(
            score_ops as f64 / wl.queries.len() as f64,
            recall.value(),
            recall.std_error(),
        );
    }
    fig.series.push(series);
    Ok(fig)
}

/// Ablation (Remark 4.3): higher-order scores `Σ ⟨x,x^μ⟩^{2m}` — argmax
/// error rate vs class size k for m = 1, 2, 3 (dense patterns, q=2).
pub fn ablation_higher_order(opts: &EvalOptions) -> Result<Figure> {
    use crate::memory::HigherOrderScorer;
    let mut fig = Figure::new(
        "ablation_higher_order",
        "Higher-order scores (Remark 4.3): error vs k for order 2m (dense d=24, q=2)",
        "k",
        "error_rate",
    );
    let d = 24usize;
    let q = 2usize;
    let trials = opts.trials(300);
    let ks = [64usize, 256, 1024, 4096, 16384];
    for &m in &[1u32, 2, 3] {
        let mut series = Series::new(format!("order_2m={}", 2 * m));
        let points: Vec<(f64, Recall)> = parallel_map_items(&ks, |&k| {
            let mut recall = Recall::new();
            let dbs = 3usize;
            for db in 0..dbs {
                let mut rng =
                    Rng::new(opts.seed ^ (k as u64) ^ ((db as u64) << 32) ^ m as u64);
                let classes: Vec<crate::data::Dataset> = (0..q)
                    .map(|_| crate::data::synthetic::dense_patterns(d, k, &mut rng))
                    .collect();
                let scorer = HigherOrderScorer::new(classes.clone(), m);
                for t in 0..(trials / dbs).max(10) {
                    let target = t % q;
                    let x = classes[target].get(t % k).to_vec();
                    let scores = scorer.score_all(&x);
                    let win = (0..q)
                        .all(|i| i == target || scores[i] < scores[target]);
                    recall.record(win);
                }
            }
            (k as f64, recall)
        });
        for (k, r) in points {
            series.push_aux(k, r.error_rate(), r.std_error());
        }
        fig.series.push(series);
    }
    Ok(fig)
}

/// Ablation (conclusion / "smart pooling"): Hopfield-readout retrieval
/// vs in-class scan — success rate of the pooled (scan-free) path and
/// total cost, as the per-class load k/d varies.
pub fn ablation_pooling(opts: &EvalOptions) -> Result<Figure> {
    use crate::index::PoolingIndex;
    let mut fig = Figure::new(
        "ablation_pooling",
        "Smart pooling (Hopfield readout) vs scan (dense d=256, q=8, alpha=0.9)",
        "k",
        "rate",
    );
    let d = 256usize;
    let q = 8usize;
    let n_queries = opts.trials(300);
    let mut pooled_series = Series::new("pooled_fraction");
    let mut recall_series = Series::new("recall_at_1");
    let mut cost_series = Series::new("relative_cost_vs_scan");
    for &k in &[8usize, 16, 32, 64, 128] {
        let mut rng = Rng::new(opts.seed ^ k as u64);
        let wl = crate::data::synthetic::dense_workload(
            d,
            k * q,
            n_queries,
            crate::data::synthetic::QueryModel::Corrupted { alpha: 0.9 },
            &mut rng,
        );
        let params = IndexParams { n_classes: q, ..Default::default() };
        let index = AmIndex::build(wl.base.clone(), params, &mut rng)?;
        let pool = PoolingIndex::new(index.clone());
        let mut pooled = Recall::new();
        let mut recall = Recall::new();
        let mut ops_pool = OpsCounter::new();
        let mut ops_scan = OpsCounter::new();
        for (qi, &gt) in wl.ground_truth.iter().enumerate() {
            let r = pool.query(wl.queries.get(qi), 1, &mut ops_pool);
            pooled.record(r.pooled);
            recall.record(r.result.id() == gt);
            index.query(wl.queries.get(qi), 1, &mut ops_scan);
        }
        pooled_series.push(k as f64, pooled.value());
        recall_series.push(k as f64, recall.value());
        cost_series.push(
            k as f64,
            ops_pool.total() as f64 / ops_scan.total().max(1) as f64,
        );
    }
    fig.series.push(pooled_series);
    fig.series.push(recall_series);
    fig.series.push(cost_series);
    Ok(fig)
}

/// Run one figure by id ("1".."12", "knn", "ablation_rule",
/// "ablation_corruption", ...).
pub fn run_figure(id: &str, opts: &EvalOptions) -> Result<Figure> {
    match id {
        "1" | "fig1" => Ok(fig1(opts)),
        "2" | "fig2" => Ok(fig2(opts)),
        "3" | "fig3" => Ok(fig3(opts)),
        "4" | "fig4" => Ok(fig4(opts)),
        "5" | "fig5" => Ok(fig5(opts)),
        "6" | "fig6" => Ok(fig6(opts)),
        "7" | "fig7" => Ok(fig7(opts)),
        "8" | "fig8" => Ok(fig8(opts)),
        "9" | "fig9" => fig9(opts),
        "10" | "fig10" => fig10(opts),
        "11" | "fig11" => fig11(opts),
        "12" | "fig12" => fig12(opts),
        "knn" | "eval_knn" => super::knn::run_knn_eval(opts),
        "quant" | "eval_quant" => super::quant::run_quant_eval(opts),
        "ablation_rule" => Ok(ablation_rule(opts)),
        "ablation_corruption" => Ok(ablation_corruption(opts)),
        "ablation_hierarchical" => ablation_hierarchical(opts),
        "ablation_higher_order" => ablation_higher_order(opts),
        "ablation_pooling" => ablation_pooling(opts),
        other => Err(crate::error::Error::Config(format!("unknown figure '{other}'"))),
    }
}

/// All figure ids in order.
pub const ALL_FIGURES: &[&str] = &[
    "1", "2", "3", "4", "5", "6", "7", "8", "9", "10", "11", "12",
    "knn", "quant", "ablation_rule", "ablation_corruption",
    "ablation_hierarchical", "ablation_higher_order", "ablation_pooling",
];

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> EvalOptions {
        EvalOptions { scale: 0.02, seed: 7 }
    }

    #[test]
    fn fig1_has_monotonic_tendency() {
        let fig = fig1(&tiny());
        let pts = &fig.series[0].points;
        assert_eq!(pts.len(), 11);
        // error at the largest k should exceed error at the smallest
        assert!(pts.last().unwrap().1 >= pts.first().unwrap().1);
    }

    #[test]
    fn fig9_runs_small() {
        let fig = fig9(&tiny()).unwrap();
        assert!(!fig.series.is_empty());
        for s in &fig.series {
            for &(x, y, _) in &s.points {
                assert!(x > 0.0, "complexity must be positive");
                assert!((0.0..=1.0).contains(&y), "recall in [0,1]");
            }
        }
    }

    #[test]
    fn recall_monotone_in_p_for_am_curve() {
        let fig = fig10(&EvalOptions { scale: 0.02, seed: 9 }).unwrap();
        let am = fig
            .series
            .iter()
            .find(|s| s.label.starts_with("am_"))
            .expect("am series");
        // points are generated with increasing p -> recall must not drop
        for w in am.points.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-9, "recall not monotone: {:?}", am.points);
        }
    }

    #[test]
    fn run_figure_rejects_unknown() {
        assert!(run_figure("nope", &tiny()).is_err());
    }
}
