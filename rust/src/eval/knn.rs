//! The paper's classification scenario: k-NN majority-vote
//! classification and recall@k evaluation over the AM index.
//!
//! The paper motivates the system with "classification and object
//! retrieval" — both consume the k nearest neighbors, not just the
//! first.  This module provides:
//!
//! * [`knn_classify`] — deterministic majority vote over the labels of
//!   the returned neighbors (ties resolve to the label whose nearest
//!   representative comes first in ascending-distance order);
//! * [`run_knn_eval`] — the eval-runner mode: recall@k curves
//!   (k ∈ {1, 5, 10, 100}) and k-NN classification accuracy, both as a
//!   function of the polled-classes budget `p`, on the labeled
//!   MNIST-like surrogate.  Ground-truth top-k comes from
//!   [`Exhaustive::query_k`].

use crate::baseline::Exhaustive;
use crate::data::mnist_like;
use crate::data::rng::Rng;
use crate::error::Result;
use crate::index::{AmIndex, IndexParams};
use crate::metrics::{OpsCounter, Recall, RecallAtK};
use crate::partition::Allocation;
use crate::search::Neighbor;
use crate::util::par::parallel_map;

use super::figures::EvalOptions;
use super::report::{Figure, Series};

/// Majority-vote classification over k-NN results.
///
/// `neighbors` must be sorted nearest-first (the contract of every
/// `query_k`); `labels[id]` is the class label of database vector `id`.
/// Returns `None` when `neighbors` is empty.  Vote ties resolve to the
/// label whose first (nearest) representative appears earliest — the
/// deterministic "nearest wins" rule, independent of label numbering.
pub fn knn_classify(neighbors: &[Neighbor], labels: &[u32]) -> Option<u32> {
    // (label, votes, first rank) per distinct label, in first-seen order
    let mut tally: Vec<(u32, usize, usize)> = Vec::new();
    for (rank, n) in neighbors.iter().enumerate() {
        let label = labels[n.id as usize];
        match tally.iter_mut().find(|(l, _, _)| *l == label) {
            Some((_, votes, _)) => *votes += 1,
            None => tally.push((label, 1, rank)),
        }
    }
    tally
        .into_iter()
        .max_by(|a, b| a.1.cmp(&b.1).then(b.2.cmp(&a.2)))
        .map(|(label, _, _)| label)
}

/// The ks the eval sweeps (clamped to the database size at run time).
pub const EVAL_KS: &[usize] = &[1, 5, 10, 100];

/// The k-NN eval-runner mode: one figure with a `recall@k` series per
/// k ∈ [`EVAL_KS`] and an `accuracy@k` (majority-vote classification)
/// series per k, each swept over the polled-classes budget `p` (the x
/// axis).  Workload: the labeled MNIST-like surrogate, greedy
/// allocation (the regime where polling few classes is interesting).
pub fn run_knn_eval(opts: &EvalOptions) -> Result<Figure> {
    let n = ((2_000.0 * opts.scale).round() as usize).max(200);
    let n_queries = ((200.0 * opts.scale).round() as usize).max(40);
    let mut rng = Rng::new(opts.seed);
    let lw = mnist_like::mnist_like_labeled_workload(n, n_queries, &mut rng);
    let wl = &lw.workload;
    let q = 20usize.min(n / 10).max(2);
    let params = IndexParams {
        n_classes: q,
        allocation: Allocation::Greedy,
        greedy_cap_factor: Some(4.0),
        ..Default::default()
    };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng)?;
    let reference = Exhaustive::new(wl.base.clone(), params.metric);
    let ks: Vec<usize> = EVAL_KS.iter().map(|&k| k.min(n)).collect();
    let k_max = *ks.iter().max().expect("EVAL_KS non-empty");
    // exact top-k_max ground truth, computed once per query
    let truth: Vec<Vec<u32>> = parallel_map(wl.queries.len(), |qi| {
        let mut ops = OpsCounter::new();
        reference
            .query_k(wl.queries.get(qi), k_max, &mut ops)
            .into_iter()
            .map(|nb| nb.id)
            .collect()
    });

    let mut ps: Vec<usize> = vec![1, 2, 4, 8, 16];
    ps.retain(|&p| p <= q);
    if ps.last() != Some(&q) {
        ps.push(q);
    }

    let mut fig = Figure::new(
        "knn",
        format!(
            "k-NN serving eval (MNIST-like surrogate, n={n}, q={q}): \
             recall@k and majority-vote accuracy vs polled classes p"
        ),
        "p",
        "recall_or_accuracy",
    );
    let mut recall_series: Vec<Series> =
        ks.iter().map(|k| Series::new(format!("recall@{k}"))).collect();
    let mut acc_series: Vec<Series> =
        ks.iter().map(|k| Series::new(format!("accuracy@{k}"))).collect();
    for &p in &ps {
        // one k_max query per (query, p); every k is a prefix of it
        let answers: Vec<Vec<Neighbor>> = parallel_map(wl.queries.len(), |qi| {
            let mut ops = OpsCounter::new();
            index.query_k(wl.queries.get(qi), p, k_max, &mut ops).neighbors
        });
        for (ki, &k) in ks.iter().enumerate() {
            let mut recall = RecallAtK::new(k);
            let mut accuracy = Recall::new();
            for (qi, full) in answers.iter().enumerate() {
                let top: Vec<u32> =
                    full.iter().take(k).map(|nb| nb.id).collect();
                recall.record(&top, &truth[qi]);
                let predicted = knn_classify(&full[..full.len().min(k)], &lw.base_labels);
                accuracy.record(predicted == Some(lw.query_labels[qi]));
            }
            recall_series[ki].push(p as f64, recall.value());
            acc_series[ki].push(p as f64, accuracy.value());
        }
    }
    fig.series.extend(recall_series);
    fig.series.extend(acc_series);
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nb(id: u32, distance: f32) -> Neighbor {
        Neighbor { id, distance }
    }

    #[test]
    fn classify_majority_wins() {
        let labels = vec![0u32, 0, 1, 1, 1];
        let ns = vec![nb(0, 0.1), nb(2, 0.2), nb(3, 0.3), nb(4, 0.4)];
        assert_eq!(knn_classify(&ns, &labels), Some(1));
    }

    #[test]
    fn classify_tie_resolves_to_nearest_first_label() {
        let labels = vec![7u32, 3, 7, 3];
        // 2 votes each; label 7's nearest rep (rank 0) beats label 3's
        let ns = vec![nb(0, 0.1), nb(1, 0.2), nb(2, 0.3), nb(3, 0.4)];
        assert_eq!(knn_classify(&ns, &labels), Some(7));
        // reverse the ranks: label 3 now wins the tie
        let ns = vec![nb(1, 0.1), nb(0, 0.2), nb(3, 0.3), nb(2, 0.4)];
        assert_eq!(knn_classify(&ns, &labels), Some(3));
    }

    #[test]
    fn classify_empty_is_none() {
        assert_eq!(knn_classify(&[], &[1, 2, 3]), None);
    }

    #[test]
    fn classify_k1_is_nearest_label() {
        let labels = vec![9u32, 4];
        assert_eq!(knn_classify(&[nb(1, 0.5)], &labels), Some(4));
    }

    #[test]
    fn knn_eval_runs_small_and_behaves() {
        let fig = run_knn_eval(&EvalOptions { scale: 0.05, seed: 11 }).unwrap();
        // one recall + one accuracy series per k
        assert_eq!(fig.series.len(), 2 * EVAL_KS.len());
        for s in &fig.series {
            assert!(!s.points.is_empty(), "{} empty", s.label);
            for &(x, y, _) in &s.points {
                assert!(x >= 1.0, "p >= 1");
                assert!((0.0..=1.0).contains(&y), "{}: y={y} out of range", s.label);
            }
        }
        // recall@k at full poll is exact: the scan covers everything, so
        // the returned top-k IS the true top-k
        for s in fig.series.iter().filter(|s| s.label.starts_with("recall@")) {
            let (_, y, _) = *s.points.last().expect("has full-poll point");
            assert!(
                (y - 1.0).abs() < 1e-9,
                "{} at full poll = {y}, want 1.0",
                s.label
            );
        }
    }
}
