//! Evaluation harness: regenerates every table/figure of the paper's §5
//! (see DESIGN.md §5 for the experiment index).

pub mod figures;
pub mod knn;
pub mod quant;
pub mod report;
pub mod runner;

pub use figures::{run_figure, EvalOptions, ALL_FIGURES};
pub use knn::{knn_classify, run_knn_eval};
pub use quant::run_quant_eval;
pub use report::{Figure, Series};
pub use runner::{class_selection_trials, PatternModel, TrialConfig};
