//! Monte-Carlo machinery for the synthetic experiments (Figures 1–8).
//!
//! Each trial asks: does the class containing the query's true match
//! achieve the (strictly) highest score?  The error event mirrors the
//! theorems' union bound `P(∃ i ≥ 2 : s(X^i) ≥ s(X^1))` — ties count as
//! errors.
//!
//! To keep very large `n = k·q` affordable, databases are built
//! *streaming*: patterns are generated, folded into the class memories,
//! and discarded; only one designated representative pattern per class is
//! retained as a query target (any stored pattern is statistically
//! equivalent under the i.i.d. model).

use crate::data::rng::Rng;
use crate::data::synthetic::{corrupt_dense, corrupt_sparse};
use crate::memory::{CooccurrenceMemory, OuterProductMemory, StorageRule};
use crate::metrics::Recall;
use crate::util::par::parallel_map;

/// Pattern model for a synthetic error-rate experiment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PatternModel {
    /// Sparse 0/1, `P(x=1) = ones/d`.
    Sparse {
        /// Expected number of ones `c`.
        ones: f64,
    },
    /// Dense unbiased ±1.
    Dense,
}

/// One synthetic experiment configuration.
#[derive(Debug, Clone, Copy)]
pub struct TrialConfig {
    /// Dimension `d`.
    pub d: usize,
    /// Class size `k`.
    pub k: usize,
    /// Number of classes `q`.
    pub q: usize,
    /// Pattern model.
    pub model: PatternModel,
    /// Query corruption: None = exact query (Thm 3.1/4.1),
    /// Some(alpha) = overlap α (Cor 3.2/4.2).
    pub alpha: Option<f64>,
    /// Storage rule (sum = analyzed, max = §5.1.1 ablation).
    pub rule: StorageRule,
}

/// Stacked memories plus a sample of representative stored patterns per
/// class.  Exact-query trials must probe *distinct* stored patterns —
/// probing one representative repeatedly would collapse the effective
/// Monte-Carlo sample to q per database.
struct TrialBank {
    stacked: Vec<f32>,
    /// reps[class][j]: the first `reps_per_class` stored patterns.
    reps: Vec<Vec<Vec<f32>>>,
    d: usize,
    q: usize,
}

fn gen_pattern(cfg: &TrialConfig, rng: &mut Rng) -> Vec<f32> {
    match cfg.model {
        PatternModel::Sparse { ones } => {
            let p = ones / cfg.d as f64;
            (0..cfg.d)
                .map(|_| if rng.bernoulli(p) { 1.0 } else { 0.0 })
                .collect()
        }
        PatternModel::Dense => (0..cfg.d)
            .map(|_| if rng.next_u64() & 1 == 1 { 1.0 } else { -1.0 })
            .collect(),
    }
}

fn build_bank(cfg: &TrialConfig, reps_per_class: usize, rng: &mut Rng) -> TrialBank {
    let (d, k, q) = (cfg.d, cfg.k, cfg.q);
    let keep = reps_per_class.clamp(1, k);
    let mut stacked = Vec::with_capacity(q * d * d);
    let mut reps = Vec::with_capacity(q);
    for _ in 0..q {
        let mut class_reps = Vec::with_capacity(keep);
        match cfg.rule {
            StorageRule::Sum => {
                let mut mem = OuterProductMemory::new(d);
                for j in 0..k {
                    let x = gen_pattern(cfg, rng);
                    mem.add(&x);
                    if j < keep {
                        class_reps.push(x);
                    }
                }
                stacked.extend_from_slice(mem.weights());
            }
            StorageRule::Max => {
                let mut mem = CooccurrenceMemory::new(d);
                for j in 0..k {
                    let x = gen_pattern(cfg, rng);
                    mem.add(&x);
                    if j < keep {
                        class_reps.push(x);
                    }
                }
                stacked.extend(mem.weights());
            }
        }
        reps.push(class_reps);
    }
    TrialBank { stacked, reps, d, q }
}

impl TrialBank {
    /// Score of class `i` for query `x` (support path for binary data).
    fn score(&self, i: usize, x: &[f32], support: Option<&[u32]>) -> f32 {
        let w = &self.stacked[i * self.d * self.d..(i + 1) * self.d * self.d];
        if let Some(sup) = support {
            let mut total = 0f32;
            for &l in sup {
                let row = &w[l as usize * self.d..(l as usize + 1) * self.d];
                for &m in sup {
                    total += row[m as usize];
                }
            }
            total
        } else {
            let mut total = 0f32;
            for (l, &xl) in x.iter().enumerate() {
                if xl == 0.0 {
                    continue;
                }
                let row = &w[l * self.d..(l + 1) * self.d];
                let mut acc = 0f32;
                for (wm, &xm) in row.iter().zip(x) {
                    acc += wm * xm;
                }
                total += xl * acc;
            }
            total
        }
    }

    /// True when the target class strictly beats every other class.
    fn target_wins(&self, target: usize, x: &[f32], sparse: bool) -> bool {
        let support: Option<Vec<u32>> = if sparse {
            Some(
                x.iter()
                    .enumerate()
                    .filter(|(_, &v)| v != 0.0)
                    .map(|(i, _)| i as u32)
                    .collect(),
            )
        } else {
            None
        };
        let s_target = self.score(target, x, support.as_deref());
        for i in 0..self.q {
            if i != target && self.score(i, x, support.as_deref()) >= s_target {
                return false;
            }
        }
        true
    }
}

/// Run `trials` Monte-Carlo trials of `cfg` and return the argmax-class
/// accuracy accumulator (error rate = `1 - value`).
///
/// Trials are spread over `databases` independently generated databases
/// (rayon-parallel); within a database, targets cycle over classes.
pub fn class_selection_trials(
    cfg: TrialConfig,
    trials: usize,
    databases: usize,
    seed: u64,
) -> Recall {
    let databases = databases.max(1);
    let per_db = trials.div_ceil(databases);
    let sparse = matches!(cfg.model, PatternModel::Sparse { .. });
    // distinct (class, stored-pattern) probes per database, so the
    // effective sample size really is `trials`
    let reps_per_class = per_db.div_ceil(cfg.q).clamp(1, cfg.k.min(256));
    let results: Vec<Recall> = parallel_map(databases, |db| {
        let mut rng = Rng::new(seed ^ (db as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let bank = build_bank(&cfg, reps_per_class, &mut rng);
        let mut recall = Recall::new();
        for t in 0..per_db {
            let target = t % cfg.q;
            let rep_idx = (t / cfg.q) % bank.reps[target].len();
            let rep = &bank.reps[target][rep_idx];
            let query: Vec<f32> = match cfg.alpha {
                None => rep.clone(),
                Some(a) => {
                    if sparse {
                        corrupt_sparse(rep, a, &mut rng)
                    } else {
                        corrupt_dense(rep, a, &mut rng)
                    }
                }
            };
            recall.record(bank.target_wins(target, &query, sparse));
        }
        recall
    });
    let mut total = Recall::new();
    for r in &results {
        total.merge(r);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_good_regime_low_error() {
        // d=128, c=8, k=256 (d < k < d²), q=4: theory says near-0 error
        let cfg = TrialConfig {
            d: 128,
            k: 256,
            q: 4,
            model: PatternModel::Sparse { ones: 8.0 },
            alpha: None,
            rule: StorageRule::Sum,
        };
        let r = class_selection_trials(cfg, 200, 4, 1);
        assert!(r.error_rate() < 0.15, "error={}", r.error_rate());
    }

    #[test]
    fn sparse_error_increases_with_k() {
        let base = TrialConfig {
            d: 64,
            k: 64,
            q: 8,
            model: PatternModel::Sparse { ones: 6.0 },
            alpha: None,
            rule: StorageRule::Sum,
        };
        let small_k = class_selection_trials(base, 400, 4, 2).error_rate();
        let big = TrialConfig { k: 4096, ..base };
        let big_k = class_selection_trials(big, 400, 4, 2).error_rate();
        assert!(
            big_k > small_k + 0.05,
            "error(k=64)={small_k} error(k=4096)={big_k}"
        );
    }

    #[test]
    fn dense_good_regime_low_error() {
        // d=64, k=128 in (d, d²), q=4
        let cfg = TrialConfig {
            d: 64,
            k: 128,
            q: 4,
            model: PatternModel::Dense,
            alpha: None,
            rule: StorageRule::Sum,
        };
        let r = class_selection_trials(cfg, 200, 4, 3);
        assert!(r.error_rate() < 0.2, "error={}", r.error_rate());
    }

    #[test]
    fn corruption_hurts() {
        let cfg = TrialConfig {
            d: 64,
            k: 512,
            q: 8,
            model: PatternModel::Dense,
            alpha: None,
            rule: StorageRule::Sum,
        };
        let exact = class_selection_trials(cfg, 300, 3, 4).error_rate();
        let corrupted = class_selection_trials(
            TrialConfig { alpha: Some(0.5), ..cfg },
            300,
            3,
            4,
        )
        .error_rate();
        assert!(
            corrupted >= exact,
            "exact={exact} corrupted={corrupted}"
        );
    }

    #[test]
    fn max_rule_runs() {
        let cfg = TrialConfig {
            d: 64,
            k: 32,
            q: 4,
            model: PatternModel::Sparse { ones: 6.0 },
            alpha: None,
            rule: StorageRule::Max,
        };
        let r = class_selection_trials(cfg, 100, 2, 5);
        assert_eq!(r.total(), 100);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = TrialConfig {
            d: 32,
            k: 16,
            q: 4,
            model: PatternModel::Dense,
            alpha: None,
            rule: StorageRule::Sum,
        };
        let a = class_selection_trials(cfg, 100, 2, 9).error_rate();
        let b = class_selection_trials(cfg, 100, 2, 9).error_rate();
        assert_eq!(a, b);
    }
}
