//! The compressed-scan eval: recall@k and k-NN classification accuracy
//! as a function of (scan precision, rerank budget) on the labeled
//! MNIST-like workload — `eval --figure quant`.
//!
//! The x axis is the rerank budget `r` (the number of compressed-scan
//! survivors re-scored exactly; the rightmost point is `r = n`, i.e.
//! rerank everything, which is bitwise the exact scan).  One recall@k
//! series per (precision, k ∈ {1, 10, 100}) plus one accuracy series
//! per precision, all at a fixed poll depth — so the curves show what
//! the *dimension* axis (quantization) costs on top of the paper's
//! *cardinal* axis (class polling), and how `r` buys it back.
//!
//! Each series queries at its own `k` and sweeps `r` as *multiples of
//! k* (`r ∈ {k, 4k, 16k, n}`): the scan clamps any budget below `k` up
//! to `k` (you must rerank at least `k` to return `k`), so sweeping a
//! fixed absolute `r` across different `k` would collapse the points
//! below `k` into the same measurement.

use crate::data::mnist_like;
use crate::data::rng::Rng;
use crate::error::Result;
use crate::index::{AmIndex, IndexParams};
use crate::metrics::{OpsCounter, Recall, RecallAtK};
use crate::quant::ScanPrecision;
use crate::search::Neighbor;
use crate::util::par::parallel_map;

use super::figures::EvalOptions;
use super::knn::knn_classify;
use super::report::{Figure, Series};

/// The ks the quant eval sweeps (clamped to the database size).
pub const QUANT_EVAL_KS: &[usize] = &[1, 10, 100];

/// Run the quant eval figure (see the module docs).
pub fn run_quant_eval(opts: &EvalOptions) -> Result<Figure> {
    let n = ((2_000.0 * opts.scale).round() as usize).max(300);
    let n_queries = ((200.0 * opts.scale).round() as usize).max(40);
    let mut rng = Rng::new(opts.seed);
    let lw = mnist_like::mnist_like_labeled_workload(n, n_queries, &mut rng);
    let wl = &lw.workload;
    let d = wl.base.dim();
    let q = 20usize.min(n / 10).max(2);
    // the interesting pruning regime: poll a fraction of the classes
    let p = (q / 2).max(1);
    let ks: Vec<usize> = QUANT_EVAL_KS.iter().map(|&k| k.min(n)).collect();
    let k_max = *ks.iter().max().expect("non-empty");

    // exact-scan reference index: its full-poll top-k IS the ground
    // truth at this poll depth, and the `exact` series anchors the plot
    let base_params = IndexParams { n_classes: q, ..Default::default() };
    let exact = AmIndex::build(wl.base.clone(), base_params, &mut Rng::new(opts.seed ^ 0xA11C))?;
    // recall is measured against the exact scan at the SAME poll depth:
    // this isolates what quantization costs (the polling loss is the
    // knn figure's subject, not this one's)
    let truth: Vec<Vec<u32>> = parallel_map(wl.queries.len(), |qi| {
        let mut ops = OpsCounter::new();
        exact
            .query_k(wl.queries.get(qi), p, k_max, &mut ops)
            .neighbors
            .into_iter()
            .map(|nb| nb.id)
            .collect()
    });

    // rerank sweep per k, in multiples of k so no point clamps into its
    // neighbor; 0 = everything scanned (plotted at x = n)
    let rerank_factors: &[usize] = &[1, 4, 16, 0];
    let m = if d % 8 == 0 { 8 } else { 1 };
    let precisions: Vec<ScanPrecision> = vec![
        ScanPrecision::Sq8 { rerank: 0 },
        ScanPrecision::Pq { m, bits: 4, rerank: 0 },
    ];

    let mut fig = Figure::new(
        "quant",
        format!(
            "compressed scan eval (MNIST-like, n={n}, d={d}, q={q}, p={p}): \
             recall@k vs exact scan and majority-vote accuracy, by \
             (precision, rerank)"
        ),
        "rerank",
        "recall_or_accuracy",
    );
    for precision in precisions {
        // train codebooks once per precision; the rerank sweep only
        // retargets the budget (set_scan_rerank, no retraining)
        let mut index = AmIndex::build(
            wl.base.clone(),
            IndexParams { n_classes: q, precision, ..Default::default() },
            &mut Rng::new(opts.seed ^ 0xA11C),
        )?;
        let mode = precision.mode();
        for &k in &ks {
            let mut recall_series = Series::new(format!("{mode}_recall@{k}"));
            let mut acc_series =
                (k == 10.min(n)).then(|| Series::new(format!("{mode}_accuracy@{k}")));
            for &f in rerank_factors {
                // each point queries at THIS k with budget r = f·k, so
                // the scan's r≥k clamp never collapses two points; a
                // budget already covering the database duplicates the
                // final rerank-everything point and is skipped
                let r = f * k;
                if f != 0 && r >= n {
                    continue;
                }
                index.set_scan_rerank(r);
                let x_val = if r == 0 { n as f64 } else { r as f64 };
                let answers: Vec<Vec<Neighbor>> =
                    parallel_map(wl.queries.len(), |qi| {
                        let mut ops = OpsCounter::new();
                        index.query_k(wl.queries.get(qi), p, k, &mut ops).neighbors
                    });
                let mut recall = RecallAtK::new(k);
                for (qi, got) in answers.iter().enumerate() {
                    let top: Vec<u32> = got.iter().map(|nb| nb.id).collect();
                    recall.record(&top, &truth[qi]);
                }
                recall_series.push(x_val, recall.value());
                if let Some(acc) = acc_series.as_mut() {
                    let mut accuracy = Recall::new();
                    for (qi, got) in answers.iter().enumerate() {
                        let predicted = knn_classify(got, &lw.base_labels);
                        accuracy.record(predicted == Some(lw.query_labels[qi]));
                    }
                    acc.push(x_val, accuracy.value());
                }
            }
            fig.series.push(recall_series);
            if let Some(acc) = acc_series {
                fig.series.push(acc);
            }
        }
    }
    Ok(fig)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quant_eval_runs_small_and_behaves() {
        let fig = run_quant_eval(&EvalOptions { scale: 0.05, seed: 13 }).unwrap();
        // per precision: one recall series per k + one accuracy series
        assert_eq!(fig.series.len(), 2 * (QUANT_EVAL_KS.len() + 1));
        for s in &fig.series {
            assert!(!s.points.is_empty(), "{} empty", s.label);
            for &(x, y, _) in &s.points {
                assert!(x >= 1.0, "{}: rerank x = {x}", s.label);
                assert!((0.0..=1.0).contains(&y), "{}: y={y}", s.label);
            }
        }
        for s in fig.series.iter().filter(|s| s.label.contains("recall@")) {
            // recall is monotone in the rerank budget (nested survivor
            // sets) ...
            for w in s.points.windows(2) {
                assert!(
                    w[1].1 >= w[0].1 - 1e-9,
                    "{} not monotone: {:?}",
                    s.label,
                    s.points
                );
            }
            // ... and rerank-everything IS the exact scan at the same
            // poll depth: recall vs that reference must be exactly 1
            let (_, y, _) = *s.points.last().expect("has full-rerank point");
            assert!(
                (y - 1.0).abs() < 1e-9,
                "{} at full rerank = {y}, want 1.0",
                s.label
            );
        }
    }
}
