//! CSV emission for the figure harnesses.

use std::io::Write;
use std::path::Path;

use crate::error::Result;

/// A labelled data series (one curve of a figure).
#[derive(Debug, Clone)]
pub struct Series {
    /// Curve label (legend entry).
    pub label: String,
    /// (x, y) points plus an optional auxiliary column (e.g. std error).
    pub points: Vec<(f64, f64, Option<f64>)>,
}

impl Series {
    /// New empty series.
    pub fn new(label: impl Into<String>) -> Self {
        Series { label: label.into(), points: Vec::new() }
    }

    /// Append a point.
    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y, None));
    }

    /// Append a point with an auxiliary value.
    pub fn push_aux(&mut self, x: f64, y: f64, aux: f64) {
        self.points.push((x, y, Some(aux)));
    }
}

/// A figure: id, axis names, series.
#[derive(Debug, Clone)]
pub struct Figure {
    /// e.g. "fig1".
    pub id: String,
    /// Plot title (matches the paper caption).
    pub title: String,
    /// X axis name.
    pub x_label: String,
    /// Y axis name.
    pub y_label: String,
    /// Data series.
    pub series: Vec<Series>,
}

impl Figure {
    /// New empty figure.
    pub fn new(
        id: impl Into<String>,
        title: impl Into<String>,
        x_label: impl Into<String>,
        y_label: impl Into<String>,
    ) -> Self {
        Figure {
            id: id.into(),
            title: title.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            series: Vec::new(),
        }
    }

    /// Write `<out_dir>/<id>.csv` with columns `series,x,y,aux`.
    pub fn write_csv(&self, out_dir: &Path) -> Result<std::path::PathBuf> {
        std::fs::create_dir_all(out_dir)?;
        let path = out_dir.join(format!("{}.csv", self.id));
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
        writeln!(f, "# {}", self.title)?;
        writeln!(f, "# x: {}, y: {}", self.x_label, self.y_label)?;
        writeln!(f, "series,{},{},aux", self.x_label, self.y_label)?;
        for s in &self.series {
            for &(x, y, aux) in &s.points {
                match aux {
                    Some(a) => writeln!(f, "{},{},{},{}", s.label, x, y, a)?,
                    None => writeln!(f, "{},{},{},", s.label, x, y)?,
                }
            }
        }
        f.flush()?;
        Ok(path)
    }

    /// Render an ASCII summary table (printed by the eval CLI).
    pub fn ascii_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} — {} ==\n", self.id, self.title));
        out.push_str(&format!(
            "{:>24} {:>14} {:>14}\n",
            "series", self.x_label, self.y_label
        ));
        for s in &self.series {
            for &(x, y, _) in &s.points {
                out.push_str(&format!("{:>24} {:>14.6} {:>14.6}\n", s.label, x, y));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let dir = std::env::temp_dir()
            .join(format!("amsearch_report_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut fig = Figure::new("figtest", "Title", "k", "error_rate");
        let mut s = Series::new("q=10");
        s.push(64.0, 0.01);
        s.push_aux(128.0, 0.02, 0.001);
        fig.series.push(s);
        let path = fig.write_csv(&dir).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.contains("series,k,error_rate,aux"));
        assert!(text.contains("q=10,64,0.01,"));
        assert!(text.contains("q=10,128,0.02,0.001"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ascii_table_contains_points() {
        let mut fig = Figure::new("f", "T", "x", "y");
        let mut s = Series::new("a");
        s.push(1.0, 2.0);
        fig.series.push(s);
        let t = fig.ascii_table();
        assert!(t.contains("f — T"));
        assert!(t.contains("1.0"));
    }
}
