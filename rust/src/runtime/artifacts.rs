//! AOT artifact manifest — the contract between `python/compile/aot.py`
//! and the rust runtime.
//!
//! `artifacts/manifest.json` lists every lowered HLO module with its
//! operand/result shapes; the runtime selects artifacts by kind and
//! shape, never by filename convention.  Parsed with the in-tree JSON
//! parser (`util::json`), since the offline build has no serde.

use crate::error::{Error, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Tensor spec in the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Dimension sizes.
    pub shape: Vec<usize>,
    /// Element dtype (currently always "f32").
    pub dtype: String,
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Unique artifact name.
    pub name: String,
    /// Graph kind: "class_scores" | "class_distances".
    pub kind: String,
    /// HLO text filename, relative to the manifest directory.
    pub file: String,
    /// Vector dimension d.
    pub d: usize,
    /// Number of classes (class_scores only).
    pub q: Option<usize>,
    /// Class size (class_distances only).
    pub k: Option<usize>,
    /// AOT batch size.
    pub b: usize,
    /// Operand specs.
    pub inputs: Vec<TensorSpec>,
    /// Result specs.
    pub outputs: Vec<TensorSpec>,
    /// Content hash of the HLO text.
    pub sha256: Option<String>,
}

/// Parsed manifest plus its directory (for resolving files).
#[derive(Debug, Clone)]
pub struct Manifest {
    dir: PathBuf,
    entries: Vec<ArtifactEntry>,
    /// Manifest schema version.
    pub version: u32,
}

fn parse_tensor_spec(v: &Json) -> Result<TensorSpec> {
    let shape = v
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Artifact("tensor spec missing shape".into()))?
        .iter()
        .map(|x| {
            x.as_usize()
                .ok_or_else(|| Error::Artifact("non-integer shape entry".into()))
        })
        .collect::<Result<Vec<usize>>>()?;
    let dtype = v
        .get("dtype")
        .and_then(Json::as_str)
        .unwrap_or("f32")
        .to_string();
    Ok(TensorSpec { shape, dtype })
}

fn parse_entry(v: &Json) -> Result<ArtifactEntry> {
    let field_str = |key: &str| -> Result<String> {
        v.get(key)
            .and_then(Json::as_str)
            .map(|s| s.to_string())
            .ok_or_else(|| Error::Artifact(format!("artifact entry missing '{key}'")))
    };
    let field_usize = |key: &str| -> Result<usize> {
        v.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| Error::Artifact(format!("artifact entry missing '{key}'")))
    };
    let specs = |key: &str| -> Result<Vec<TensorSpec>> {
        v.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Artifact(format!("artifact entry missing '{key}'")))?
            .iter()
            .map(parse_tensor_spec)
            .collect()
    };
    Ok(ArtifactEntry {
        name: field_str("name")?,
        kind: field_str("kind")?,
        file: field_str("file")?,
        d: field_usize("d")?,
        q: v.get("q").and_then(Json::as_usize),
        k: v.get("k").and_then(Json::as_usize),
        b: field_usize("b")?,
        inputs: specs("inputs")?,
        outputs: specs("outputs")?,
        sha256: v.get("sha256").and_then(Json::as_str).map(|s| s.to_string()),
    })
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts`): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (dir is used for file resolution).
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let root = Json::parse(text)?;
        let version = root
            .get("version")
            .and_then(Json::as_u64)
            .ok_or_else(|| Error::Artifact("manifest missing version".into()))?
            as u32;
        if version != 1 {
            return Err(Error::Artifact(format!(
                "unsupported manifest version {version}"
            )));
        }
        let entries = root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Artifact("manifest missing artifacts".into()))?
            .iter()
            .map(parse_entry)
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir: dir.to_path_buf(), entries, version })
    }

    /// All entries.
    pub fn entries(&self) -> &[ArtifactEntry] {
        &self.entries
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Find a `class_scores` artifact for exactly (d, q).
    pub fn find_scores(&self, d: usize, q: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == "class_scores" && e.d == d && e.q == Some(q))
    }

    /// Find a `class_distances` artifact for exactly (d, k).
    pub fn find_distances(&self, d: usize, k: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == "class_distances" && e.d == d && e.k == Some(k))
    }

    /// Find a `build_bank` artifact for exactly (d, q, k).
    pub fn find_build_bank(&self, d: usize, q: usize, k: usize) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| {
            e.kind == "build_bank" && e.d == d && e.q == Some(q) && e.k == Some(k)
        })
    }

    /// Verify the on-disk HLO of `entry` against its manifest sha256.
    /// Returns Ok(()) for entries without a recorded hash.
    pub fn verify(&self, entry: &ArtifactEntry) -> Result<()> {
        let Some(expected) = &entry.sha256 else { return Ok(()) };
        let path = self.path_of(entry);
        let data = std::fs::read(&path).map_err(|e| {
            Error::Artifact(format!("cannot read {}: {e}", path.display()))
        })?;
        let got = crate::util::sha256::hex_digest(&data);
        if &got != expected {
            return Err(Error::Artifact(format!(
                "{}: sha256 mismatch (manifest {expected}, file {got}) — \
                 stale artifact, re-run `make artifacts`",
                entry.name
            )));
        }
        Ok(())
    }

    /// Verify every entry (used at runtime startup).
    pub fn verify_all(&self) -> Result<()> {
        for e in &self.entries {
            self.verify(e)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "artifacts": [
            {
                "name": "class_scores_d8_q4_b2",
                "kind": "class_scores",
                "file": "class_scores_d8_q4_b2.hlo.txt",
                "d": 8, "q": 4, "b": 2,
                "inputs": [
                    {"shape": [4, 8, 8], "dtype": "f32"},
                    {"shape": [2, 8], "dtype": "f32"}
                ],
                "outputs": [{"shape": [2, 4], "dtype": "f32"}],
                "sha256": "abc"
            },
            {
                "name": "class_distances_d8_k16_b2",
                "kind": "class_distances",
                "file": "class_distances_d8_k16_b2.hlo.txt",
                "d": 8, "k": 16, "b": 2,
                "inputs": [
                    {"shape": [16, 8], "dtype": "f32"},
                    {"shape": [2, 8], "dtype": "f32"}
                ],
                "outputs": [{"shape": [2, 16], "dtype": "f32"}]
            }
        ]
    }"#;

    #[test]
    fn parse_and_find() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries().len(), 2);
        let s = m.find_scores(8, 4).unwrap();
        assert_eq!(s.b, 2);
        assert_eq!(s.inputs[0].shape, vec![4, 8, 8]);
        assert_eq!(s.sha256.as_deref(), Some("abc"));
        assert!(m.find_scores(8, 5).is_none());
        let d = m.find_distances(8, 16).unwrap();
        assert_eq!(d.outputs[0].shape, vec![2, 16]);
        assert!(d.sha256.is_none());
        assert!(m.find_distances(9, 16).is_none());
        assert_eq!(
            m.path_of(s),
            Path::new("/tmp/a").join("class_scores_d8_q4_b2.hlo.txt")
        );
    }

    #[test]
    fn missing_manifest_is_artifact_error() {
        let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
        assert!(matches!(err, Error::Artifact(_)));
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn wrong_version_rejected() {
        let err =
            Manifest::parse(r#"{"version": 9, "artifacts": []}"#, Path::new("/"))
                .unwrap_err();
        assert!(err.to_string().contains("version"));
    }

    #[test]
    fn missing_fields_rejected() {
        let bad = r#"{"version": 1, "artifacts": [{"name": "x"}]}"#;
        assert!(Manifest::parse(bad, Path::new("/")).is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // integration hook: if `make artifacts` already ran, the real
        // manifest must parse
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.find_scores(128, 64).is_some());
            assert!(m.find_distances(128, 256).is_some());
        }
    }
}
