//! PJRT candidate-scan executor: runs the AOT `class_distances` graph
//! (one fused GEMM) over a class's member matrix.
//!
//! Class member counts vary (greedy allocation), while the artifact has a
//! fixed `[k, d]` operand: smaller classes are zero-padded and the padded
//! rows masked out of the reduction on the rust side.

use crate::error::{Error, Result};

use super::artifacts::Manifest;
use super::xla;

/// PJRT distance scanner with fixed (k, d, b) shapes.
pub struct PjrtDistances {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    dim: usize,
    k: usize,
    batch: usize,
}

impl PjrtDistances {
    /// Compile the matching artifact.
    pub fn from_manifest(
        client: &xla::PjRtClient,
        manifest: &Manifest,
        dim: usize,
        k: usize,
    ) -> Result<Self> {
        let entry = manifest.find_distances(dim, k).ok_or_else(|| {
            Error::Artifact(format!(
                "no class_distances artifact for d={dim} k={k}; run `make artifacts`"
            ))
        })?;
        manifest.verify(entry)?;
        let path = manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(PjrtDistances { exe, client: client.clone(), dim, k, batch: entry.b })
    }

    /// Fixed class capacity `k` of the artifact.
    pub fn capacity(&self) -> usize {
        self.k
    }

    /// Fixed batch size of the artifact.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Squared-L2 distances from each query to each of the first
    /// `n_members` rows of `members` (`[n_members * d]`, padded to the
    /// artifact's `k` internally).  `queries` is `[m * d]` with `m <=
    /// batch`.  Returns `[m * n_members]`.
    pub fn distances(
        &self,
        members: &[f32],
        n_members: usize,
        queries: &[f32],
    ) -> Result<Vec<f32>> {
        if n_members == 0 || n_members > self.k {
            return Err(Error::Shape(format!(
                "n_members {} out of 1..={}",
                n_members, self.k
            )));
        }
        if members.len() != n_members * self.dim {
            return Err(Error::Shape(format!(
                "members len {} != n_members*d = {}",
                members.len(),
                n_members * self.dim
            )));
        }
        let m = queries.len() / self.dim;
        if m == 0 || m > self.batch || queries.len() % self.dim != 0 {
            return Err(Error::Shape(format!(
                "queries len {} must be 1..={} rows of d={}",
                queries.len(),
                self.batch,
                self.dim
            )));
        }
        let mut v = vec![0f32; self.k * self.dim];
        v[..members.len()].copy_from_slice(members);
        let mut x = vec![0f32; self.batch * self.dim];
        x[..queries.len()].copy_from_slice(queries);
        let v_buf = self.client.buffer_from_host_buffer(&v, &[self.k, self.dim], None)?;
        let x_buf =
            self.client.buffer_from_host_buffer(&x, &[self.batch, self.dim], None)?;
        let result = self.exe.execute_b(&[&v_buf, &x_buf])?;
        let literal = result[0][0].to_literal_sync()?;
        let out = literal.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        if values.len() != self.batch * self.k {
            return Err(Error::Runtime(format!(
                "distances shape mismatch: got {}, want {}",
                values.len(),
                self.batch * self.k
            )));
        }
        // strip padding: keep first n_members of each of the m rows
        let mut trimmed = Vec::with_capacity(m * n_members);
        for row in 0..m {
            let start = row * self.k;
            trimmed.extend_from_slice(&values[start..start + n_members]);
        }
        Ok(trimmed)
    }

    /// Like [`Self::distances`] but for any number of query rows:
    /// submits `ceil(m / batch)` executions against the same member
    /// matrix.  This is the class-major entry point of the batched
    /// pipeline — all queries that polled one class go through here in
    /// as few GEMMs as the artifact's fixed batch allows.
    pub fn distances_chunked(
        &self,
        members: &[f32],
        n_members: usize,
        queries: &[f32],
    ) -> Result<Vec<f32>> {
        let full = self.batch * self.dim;
        if queries.len() <= full {
            return self.distances(members, n_members, queries);
        }
        if queries.len() % self.dim != 0 {
            return Err(Error::Shape(format!(
                "queries len {} not a multiple of d={}",
                queries.len(),
                self.dim
            )));
        }
        let m = queries.len() / self.dim;
        let mut out = Vec::with_capacity(m * n_members);
        let mut offset = 0;
        while offset < queries.len() {
            let end = (offset + full).min(queries.len());
            out.extend(self.distances(members, n_members, &queries[offset..end])?);
            offset = end;
        }
        Ok(out)
    }
}
