//! PJRT runtime: loads the AOT artifacts (HLO text, see
//! `python/compile/aot.py`) on the CPU PJRT client and executes them on
//! the request path.  The [`scorer::NativeScorer`] mirrors the PJRT
//! scorer exactly and serves as both cross-check and fallback.
//!
//! The offline build cannot vendor the `xla` crate, so [`xla`] is a
//! local API-compatible stub that fails fast at [`cpu_client`]; the
//! native backend is the production path until the real runtime is
//! vendored back in.

pub mod artifacts;
pub mod bank_builder;
pub mod distances;
pub mod scorer;
pub mod xla;

pub use artifacts::{ArtifactEntry, Manifest};
pub use bank_builder::PjrtBankBuilder;
pub use distances::PjrtDistances;
pub use scorer::{ClassScorer, NativeScorer, PjrtScorer};

use crate::error::Result;

/// Create the process-wide CPU PJRT client.
pub fn cpu_client() -> Result<xla::PjRtClient> {
    Ok(xla::PjRtClient::cpu()?)
}

/// Which scoring backend to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Optimized pure-rust scorer.
    Native,
    /// AOT Pallas/JAX artifact via PJRT.
    Pjrt,
}

impl std::str::FromStr for Backend {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt),
            other => Err(crate::error::Error::Config(format!(
                "unknown backend '{other}' (native|pjrt)"
            ))),
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Native => write!(f, "native"),
            Backend::Pjrt => write!(f, "pjrt"),
        }
    }
}
