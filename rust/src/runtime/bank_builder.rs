//! PJRT bank builder: runs the AOT `build_bank` artifact (the L1 pallas
//! construction kernel, `W_i = X_iᵀ X_i`) to build stacked memories from
//! class members.  Offline/rebuild path — the native
//! [`crate::memory::MemoryBank`] remains the default; this executor
//! exists so the whole paper pipeline (build *and* query) can run through
//! the compiled artifacts, and is cross-checked against the native build
//! in `rust/tests/runtime_pjrt.rs`.

use crate::error::{Error, Result};

use super::artifacts::Manifest;
use super::xla;

/// PJRT memory-bank builder with fixed (q, k, d) shapes.
pub struct PjrtBankBuilder {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    dim: usize,
    q: usize,
    k: usize,
}

impl PjrtBankBuilder {
    /// Compile the matching artifact.
    pub fn from_manifest(
        client: &xla::PjRtClient,
        manifest: &Manifest,
        dim: usize,
        q: usize,
        k: usize,
    ) -> Result<Self> {
        let entry = manifest.find_build_bank(dim, q, k).ok_or_else(|| {
            Error::Artifact(format!(
                "no build_bank artifact for d={dim} q={q} k={k}; run `make artifacts`"
            ))
        })?;
        manifest.verify(entry)?;
        let path = manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(PjrtBankBuilder { exe, client: client.clone(), dim, q, k })
    }

    /// Fixed class size `k` of the artifact.
    pub fn class_size(&self) -> usize {
        self.k
    }

    /// Build the `[q * d * d]` stacked bank from `[q * k * d]` members.
    /// Classes with fewer than `k` members must be zero-padded by the
    /// caller (zero rows contribute nothing to `XᵀX`).
    pub fn build(&self, members: &[f32]) -> Result<Vec<f32>> {
        if members.len() != self.q * self.k * self.dim {
            return Err(Error::Shape(format!(
                "members len {} != q*k*d = {}",
                members.len(),
                self.q * self.k * self.dim
            )));
        }
        let buf = self.client.buffer_from_host_buffer(
            members,
            &[self.q, self.k, self.dim],
            None,
        )?;
        let result = self.exe.execute_b(&[&buf])?;
        let literal = result[0][0].to_literal_sync()?;
        let out = literal.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        if values.len() != self.q * self.dim * self.dim {
            return Err(Error::Runtime(format!(
                "bank shape mismatch: got {}, want {}",
                values.len(),
                self.q * self.dim * self.dim
            )));
        }
        Ok(values)
    }
}
