//! Offline stand-in for the `xla` (PJRT) crate.
//!
//! The real dependency links the XLA/PJRT C++ runtime and cannot be
//! vendored into this offline build, so this module mirrors exactly the
//! API surface the crate uses — [`PjRtClient`], [`PjRtLoadedExecutable`],
//! [`PjRtBuffer`], [`Literal`], [`HloModuleProto`], [`XlaComputation`]
//! and [`Error`] — and fails fast at the single entry point,
//! [`PjRtClient::cpu`], with an actionable error.  Every PJRT code path
//! (scorer, candidate scanner, bank builder) keeps compiling and stays
//! covered by the shape/validation tests; the execution-dependent
//! integration tests in `rust/tests/runtime_pjrt.rs` skip themselves when
//! no artifacts are present, which is always the case without the real
//! runtime.
//!
//! Swapping the real crate back in is mechanical: delete this module,
//! add the `xla` dependency, and replace the `use super::xla;` /
//! `use crate::runtime::xla;` imports with `use xla;`.

/// Mirrors `xla::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT runtime unavailable: this is the offline build without the \
         `xla` crate; use the native backend (`--backend native`)"
            .into(),
    ))
}

/// Mirrors `xla::PjRtClient` (CPU platform).
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    /// The real call creates the process-wide CPU PJRT client; the stub
    /// fails fast so no downstream PJRT object can ever be constructed.
    pub fn cpu() -> Result<Self, Error> {
        unavailable()
    }

    /// Compile an XLA computation into a loaded executable.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }

    /// Upload a host f32 buffer as a device buffer with the given shape.
    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<()>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable()
    }
}

/// Mirrors `xla::HloModuleProto`.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        unavailable()
    }
}

/// Mirrors `xla::XlaComputation`.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Mirrors `xla::PjRtLoadedExecutable`.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with borrowed input buffers; returns per-device result
    /// buffers (`result[device][output]`).
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Mirrors `xla::PjRtBuffer`.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the device buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Mirrors `xla::Literal`.
#[derive(Debug)]
pub struct Literal;

impl Literal {
    /// Unwrap a 1-tuple literal (AOT graphs lower with `return_tuple`).
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        unavailable()
    }

    /// Copy out the elements.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_with_actionable_message() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("native"), "{msg}");
        assert!(msg.contains("offline"), "{msg}");
    }

    #[test]
    fn error_converts_to_crate_runtime_error() {
        let e: crate::error::Error = Error("boom".into()).into();
        assert!(matches!(e, crate::error::Error::Runtime(_)));
        assert!(e.to_string().contains("boom"));
    }
}
