//! Class scorers: the PJRT path (runs the AOT Pallas/JAX artifact) and
//! the native path (optimized rust mirror).  Both implement
//! [`ClassScorer`] so the coordinator and eval harness are
//! backend-agnostic, and the PJRT path is cross-checked against the
//! native one in tests.

use crate::error::{Error, Result};
use crate::memory::score as mem_score;
use crate::search::Kernels;

use super::artifacts::Manifest;
use super::xla;

/// Backend-agnostic batched class scorer.
///
/// `queries` is `[m * d]` row-major; returns `[m * q]` scores
/// `S[b, i] = x_bᵀ W_i x_b` for the bank the scorer was built with.
///
/// Deliberately NOT `Send`/`Sync`: the PJRT implementation wraps
/// `Rc`-based client state and must stay on the thread that created it.
/// Each coordinator worker thread builds its own scorer (see
/// [`crate::coordinator::engine::EngineFactory`]).
pub trait ClassScorer {
    /// Score a batch of queries against every class.
    fn score(&self, queries: &[f32]) -> Result<Vec<f32>>;
    /// Vector dimension d.
    fn dim(&self) -> usize;
    /// Number of classes q.
    fn n_classes(&self) -> usize;
    /// Human-readable backend name.
    fn backend(&self) -> &'static str;
}

/// Pure-rust scorer over an owned stacked bank.
pub struct NativeScorer {
    stacked: Vec<f32>,
    dim: usize,
    q: usize,
    /// Distance/dot kernel dispatch, selected once at construction.
    kernels: Kernels,
}

impl NativeScorer {
    /// Wrap a `[q * d * d]` stacked bank.
    pub fn new(stacked: Vec<f32>, dim: usize, q: usize) -> Result<Self> {
        if stacked.len() != q * dim * dim {
            return Err(Error::Shape(format!(
                "stacked len {} != q*d*d = {}",
                stacked.len(),
                q * dim * dim
            )));
        }
        Ok(NativeScorer { stacked, dim, q, kernels: Kernels::select() })
    }
}

impl ClassScorer for NativeScorer {
    fn score(&self, queries: &[f32]) -> Result<Vec<f32>> {
        if queries.is_empty() || queries.len() % self.dim != 0 {
            return Err(Error::Shape(format!(
                "query buffer len {} not a positive multiple of d={}",
                queries.len(),
                self.dim
            )));
        }
        Ok(mem_score::score_batch(
            &self.stacked,
            queries,
            self.dim,
            self.q,
            self.kernels,
        ))
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn n_classes(&self) -> usize {
        self.q
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

/// PJRT scorer: loads the AOT `class_scores` HLO artifact, compiles it on
/// the CPU PJRT client, uploads the memory bank once, and executes per
/// batch.  Queries are padded to the artifact's fixed batch size `b`.
pub struct PjrtScorer {
    exe: xla::PjRtLoadedExecutable,
    /// Bank uploaded once at construction; PJRT CPU does not donate
    /// non-aliased inputs, so the buffer is reusable across executions.
    w_buf: xla::PjRtBuffer,
    client: xla::PjRtClient,
    dim: usize,
    q: usize,
    batch: usize,
}

impl PjrtScorer {
    /// Compile the matching artifact from `manifest` and upload `stacked`.
    pub fn from_manifest(
        client: &xla::PjRtClient,
        manifest: &Manifest,
        stacked: &[f32],
        dim: usize,
        q: usize,
    ) -> Result<Self> {
        let entry = manifest.find_scores(dim, q).ok_or_else(|| {
            Error::Artifact(format!(
                "no class_scores artifact for d={dim} q={q}; \
                 regenerate with `make artifacts` or \
                 `python -m compile.aot --configs d={dim},q={q},b=8,k=...`"
            ))
        })?;
        manifest.verify(entry)?;
        if stacked.len() != q * dim * dim {
            return Err(Error::Shape(format!(
                "stacked len {} != q*d*d = {}",
                stacked.len(),
                q * dim * dim
            )));
        }
        let path = manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        let w_buf =
            client.buffer_from_host_buffer(stacked, &[q, dim, dim], None)?;
        Ok(PjrtScorer { exe, w_buf, client: client.clone(), dim, q, batch: entry.b })
    }

    /// The artifact's fixed batch size.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    fn execute_chunk(&self, chunk: &[f32], rows: usize) -> Result<Vec<f32>> {
        let x_buf =
            self.client
                .buffer_from_host_buffer(chunk, &[self.batch, self.dim], None)?;
        let result = self.exe.execute_b(&[&self.w_buf, &x_buf])?;
        let literal = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple
        let out = literal.to_tuple1()?;
        let values = out.to_vec::<f32>()?;
        if values.len() != self.batch * self.q {
            return Err(Error::Runtime(format!(
                "scores shape mismatch: got {} values, want {}",
                values.len(),
                self.batch * self.q
            )));
        }
        Ok(values[..rows * self.q].to_vec())
    }
}

impl ClassScorer for PjrtScorer {
    fn score(&self, queries: &[f32]) -> Result<Vec<f32>> {
        if queries.is_empty() || queries.len() % self.dim != 0 {
            return Err(Error::Shape(format!(
                "query buffer len {} not a positive multiple of d={}",
                queries.len(),
                self.dim
            )));
        }
        let m = queries.len() / self.dim;
        let mut out = Vec::with_capacity(m * self.q);
        let full = self.batch * self.dim;
        let mut offset = 0;
        while offset < queries.len() {
            let remaining = queries.len() - offset;
            if remaining >= full {
                out.extend(self.execute_chunk(
                    &queries[offset..offset + full],
                    self.batch,
                )?);
                offset += full;
            } else {
                // pad the tail chunk with zeros
                let rows = remaining / self.dim;
                let mut padded = vec![0f32; full];
                padded[..remaining].copy_from_slice(&queries[offset..]);
                out.extend(self.execute_chunk(&padded, rows)?);
                offset = queries.len();
            }
        }
        Ok(out)
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn n_classes(&self) -> usize {
        self.q
    }

    fn backend(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    #[test]
    fn native_scorer_validates_shapes() {
        assert!(NativeScorer::new(vec![0.0; 10], 2, 2).is_err());
        let s = NativeScorer::new(vec![0.0; 8], 2, 2).unwrap();
        assert!(s.score(&[1.0, 2.0, 3.0]).is_err());
        assert!(s.score(&[]).is_err());
        assert_eq!(s.backend(), "native");
    }

    #[test]
    fn native_scorer_scores() {
        // W0 = I, W1 = 2I (d=2)
        let stacked = vec![1., 0., 0., 1., 2., 0., 0., 2.];
        let s = NativeScorer::new(stacked, 2, 2).unwrap();
        let scores = s.score(&[3.0, 4.0]).unwrap();
        assert_eq!(scores, vec![25.0, 50.0]);
    }

    #[test]
    fn native_scorer_multi_batch() {
        let mut rng = Rng::new(1);
        let (q, d) = (3, 8);
        let stacked: Vec<f32> =
            (0..q * d * d).map(|_| rng.normal() as f32).collect();
        let s = NativeScorer::new(stacked.clone(), d, q).unwrap();
        let queries: Vec<f32> = (0..5 * d).map(|_| rng.normal() as f32).collect();
        let batch = s.score(&queries).unwrap();
        assert_eq!(batch.len(), 5 * q);
        // row 2 equals scoring row 2 alone
        let single = s.score(&queries[2 * d..3 * d]).unwrap();
        for i in 0..q {
            assert!((batch[2 * q + i] - single[i]).abs() < 1e-4);
        }
    }
}
