//! Compressed scan vs exact scan on the clustered workload: sweeps
//! scan precision (exact / sq8 / pq) × rerank budget at batch sizes
//! B ∈ {1, 32}, timing the select+scan stage through `finish_batch`
//! (scores precomputed outside the timed region, exactly like
//! `batch_scan.rs`, so the cells are comparable across targets).
//!
//! Set `AMSEARCH_BENCH_JSON=BENCH_quant_scan.json` to also emit the
//! measurements as a machine-readable artifact (used by CI).

#[path = "harness_common.rs"]
#[allow(dead_code)] // helpers are shared; each target uses a subset
mod harness;

use amsearch::data::clustered::{clustered_workload, ClusteredSpec};
use amsearch::data::rng::Rng;
use amsearch::index::{AmIndex, IndexParams};
use amsearch::metrics::OpsCounter;
use amsearch::quant::ScanPrecision;
use harness::{bench, budget, section, write_json_if_requested, Measurement};

fn main() {
    let mut rng = Rng::new(47);
    let (d, n, q, p) = (128usize, 16_384usize, 64usize, 4usize);
    let spec = ClusteredSpec { dim: d, n_clusters: q, ..ClusteredSpec::sift_like() };
    let n_queries = 64usize;
    let wl = clustered_workload(spec, n, n_queries, &mut rng);
    println!(
        "workload: clustered n={n} d={d} q={q} k={} p={p}",
        n / q
    );

    // one index per precision, trained once; the rerank sweep only
    // retargets the budget (set_scan_rerank — no codebook retraining)
    let precisions: &[(&str, ScanPrecision)] = &[
        ("exact", ScanPrecision::Exact),
        ("sq8", ScanPrecision::Sq8 { rerank: 0 }),
        ("pq16x4", ScanPrecision::Pq { m: 16, bits: 4, rerank: 0 }),
    ];
    let mut all: Vec<Measurement> = Vec::new();
    for &(label, precision) in precisions {
        let params = IndexParams { n_classes: q, top_p: p, precision, ..Default::default() };
        let mut index =
            AmIndex::build(wl.base.clone(), params, &mut Rng::new(48)).unwrap();
        let fp = index.footprint();
        section(&format!(
            "{label}: scan-resident {} bytes of {} f32 bytes ({:.3}x)",
            fp.compressed_bytes,
            fp.bytes,
            fp.ratio()
        ));
        // budgets strictly above k = 10: the scan clamps any budget
        // below k up to k, which would silently relabel the cell
        let reranks: &[usize] = if precision == ScanPrecision::Exact {
            &[0] // no rerank stage to sweep
        } else {
            &[16, 128]
        };
        for &r in reranks {
            index.set_scan_rerank(r);
            for &b in &[1usize, 32] {
                let queries: Vec<&[f32]> =
                    (0..b).map(|i| wl.queries.get(i % n_queries)).collect();
                let ps = vec![p; b];
                let ks = vec![10usize; b];
                let mut throwaway = OpsCounter::new();
                let mut flat_scores = Vec::with_capacity(b * q);
                for x in &queries {
                    flat_scores
                        .extend_from_slice(&index.score_classes(x, &mut throwaway));
                }
                let m = bench(
                    &format!("{label:<7} r={r:<3} B={b:<3} k=10 scan"),
                    budget(),
                    || {
                        let mut ops = vec![OpsCounter::new(); b];
                        let rs = index
                            .finish_batch(&queries, &flat_scores, &ps, &ks, &mut ops);
                        std::hint::black_box(rs.len());
                    },
                );
                m.report();
                all.push(m);
            }
        }
    }
    write_json_if_requested(&all);
}
