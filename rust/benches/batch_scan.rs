//! Class-grouped batched candidate scan vs the per-query scan, on the
//! clustered synthetic workload (the serving-realistic case: queries
//! concentrate on the same few classes, so the batch fusion actually
//! shares class slabs).
//!
//! Stage isolation: class scores are precomputed once per batch outside
//! the timed region, so both sides time exactly select + scan.  The
//! sweep covers the batch dimension B (at k = 1) and the new neighbor
//! dimension k (at fixed B), so the fusion-factor win is measured per
//! k, not assumed.  The `engine` section then times the full pipeline
//! (score + select + scan) end to end through `Engine::serve_batch`.
//!
//! Set `AMSEARCH_BENCH_JSON=BENCH_batch_scan.json` to also emit the
//! measurements as a machine-readable artifact (used by CI).

#[path = "harness_common.rs"]
#[allow(dead_code)] // helpers are shared; each target uses a subset
mod harness;

use std::sync::Arc;

use amsearch::coordinator::Engine;
use amsearch::data::clustered::{clustered_workload, ClusteredSpec};
use amsearch::data::rng::Rng;
use amsearch::index::{AmIndex, IndexParams};
use amsearch::metrics::OpsCounter;
use harness::{bench, budget, section, write_json_if_requested, Measurement};

fn main() {
    let mut rng = Rng::new(31);
    let (d, n, q, p) = (128usize, 32_768usize, 64usize, 4usize);
    let spec = ClusteredSpec { dim: d, n_clusters: q, ..ClusteredSpec::sift_like() };
    let n_queries = 64usize;
    let wl = clustered_workload(spec, n, n_queries, &mut rng);
    let params = IndexParams { n_classes: q, top_p: p, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    println!(
        "workload: clustered n={n} d={d} q={q} k={} p={p} (queries share hot classes)",
        n / q
    );
    let mut all: Vec<Measurement> = Vec::new();

    // (B, k) cells: the B sweep at k=1 (the pre-k-NN comparison) plus a
    // k sweep at B=32 (the cost of keeping more neighbors per query)
    let cells: &[(usize, usize)] =
        &[(1, 1), (8, 1), (32, 1), (64, 1), (32, 10), (32, 100)];
    section("scan stage: per-query finish_query vs class-grouped finish_batch");
    for &(b, k) in cells {
        let queries: Vec<&[f32]> =
            (0..b).map(|i| wl.queries.get(i % n_queries)).collect();
        let ps = vec![p; b];
        let ks = vec![k; b];
        // scores precomputed outside the timed region
        let mut throwaway = OpsCounter::new();
        let mut flat_scores = Vec::with_capacity(b * q);
        for x in &queries {
            flat_scores.extend_from_slice(&index.score_classes(x, &mut throwaway));
        }

        let m_seq =
            bench(&format!("per-query scan      B={b:<3} k={k:<3}"), budget(), || {
                let mut total = 0usize;
                for (bi, x) in queries.iter().enumerate() {
                    let mut ops = OpsCounter::new();
                    let r = index.finish_query(
                        x,
                        &flat_scores[bi * q..(bi + 1) * q],
                        p,
                        k,
                        &mut ops,
                    );
                    total += r.candidates;
                }
                std::hint::black_box(total);
            });
        let m_batch =
            bench(&format!("class-grouped scan  B={b:<3} k={k:<3}"), budget(), || {
                let mut ops = vec![OpsCounter::new(); b];
                let rs = index.finish_batch(&queries, &flat_scores, &ps, &ks, &mut ops);
                std::hint::black_box(rs.len());
            });
        m_seq.report();
        m_batch.report();
        println!(
            "  -> class-grouped speedup at B={b} k={k}: {:.2}x",
            m_seq.mean_ns / m_batch.mean_ns
        );
        all.push(m_seq);
        all.push(m_batch);
    }

    section("end-to-end engine pipeline (score + select + scan)");
    let engine = Engine::native(Arc::new(index)).unwrap();
    for &(b, k) in &[(1usize, 1usize), (8, 1), (32, 1), (32, 10)] {
        let queries: Vec<(&[f32], usize, usize)> =
            (0..b).map(|i| (wl.queries.get(i % n_queries), p, k)).collect();
        let m = bench(&format!("engine.serve_batch  B={b:<3} k={k:<3}"), budget(), || {
            std::hint::black_box(engine.serve_batch(&queries).unwrap());
        });
        m.report();
        let out = engine.serve_batch_detailed(&queries).unwrap();
        println!(
            "  -> per-request {:.2}us, scan fusion {:.2}x ({} polls / {} class passes)",
            m.mean_ns / b as f64 / 1e3,
            out.scan.fusion_factor(),
            out.scan.polls,
            out.scan.class_passes
        );
        all.push(m);
    }

    write_json_if_requested(&all);
}
