//! Class-grouped batched candidate scan vs the per-query scan, on the
//! clustered synthetic workload (the serving-realistic case: queries
//! concentrate on the same few classes, so the batch fusion actually
//! shares class slabs).
//!
//! Stage isolation: class scores are precomputed once per batch outside
//! the timed region, so both sides time exactly select + scan.  The
//! `engine` section then times the full pipeline (score + select +
//! scan) end to end through `Engine::serve_batch`.

#[path = "harness_common.rs"]
mod harness;

use std::sync::Arc;

use amsearch::coordinator::Engine;
use amsearch::data::clustered::{clustered_workload, ClusteredSpec};
use amsearch::data::rng::Rng;
use amsearch::index::{AmIndex, IndexParams};
use amsearch::metrics::OpsCounter;
use harness::{bench, budget, section};

fn main() {
    let mut rng = Rng::new(31);
    let (d, n, q, p) = (128usize, 32_768usize, 64usize, 4usize);
    let spec = ClusteredSpec { dim: d, n_clusters: q, ..ClusteredSpec::sift_like() };
    let n_queries = 64usize;
    let wl = clustered_workload(spec, n, n_queries, &mut rng);
    let params = IndexParams { n_classes: q, top_p: p, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    println!(
        "workload: clustered n={n} d={d} q={q} k={} p={p} (queries share hot classes)",
        n / q
    );

    section("scan stage: per-query finish_query vs class-grouped finish_batch");
    for &b in &[1usize, 8, 32, 64] {
        let queries: Vec<&[f32]> =
            (0..b).map(|i| wl.queries.get(i % n_queries)).collect();
        let ps = vec![p; b];
        // scores precomputed outside the timed region
        let mut throwaway = OpsCounter::new();
        let mut flat_scores = Vec::with_capacity(b * q);
        for x in &queries {
            flat_scores.extend_from_slice(&index.score_classes(x, &mut throwaway));
        }

        let m_seq = bench(&format!("per-query scan      B={b:<3}"), budget(), || {
            let mut total = 0usize;
            for (bi, x) in queries.iter().enumerate() {
                let mut ops = OpsCounter::new();
                let r = index.finish_query(
                    x,
                    &flat_scores[bi * q..(bi + 1) * q],
                    p,
                    &mut ops,
                );
                total += r.candidates;
            }
            std::hint::black_box(total);
        });
        let m_batch = bench(&format!("class-grouped scan  B={b:<3}"), budget(), || {
            let mut ops = vec![OpsCounter::new(); b];
            let rs = index.finish_batch(&queries, &flat_scores, &ps, &mut ops);
            std::hint::black_box(rs.len());
        });
        m_seq.report();
        m_batch.report();
        println!(
            "  -> class-grouped speedup at B={b}: {:.2}x",
            m_seq.mean_ns / m_batch.mean_ns
        );
    }

    section("end-to-end engine pipeline (score + select + scan)");
    let engine = Engine::native(Arc::new(index)).unwrap();
    for &b in &[1usize, 8, 32] {
        let queries: Vec<(&[f32], usize)> =
            (0..b).map(|i| (wl.queries.get(i % n_queries), p)).collect();
        let m = bench(&format!("engine.serve_batch  B={b:<3}"), budget(), || {
            std::hint::black_box(engine.serve_batch(&queries).unwrap());
        });
        m.report();
        let out = engine.serve_batch_detailed(&queries).unwrap();
        println!(
            "  -> per-request {:.2}us, scan fusion {:.2}x ({} polls / {} class passes)",
            m.mean_ns / b as f64 / 1e3,
            out.scan.fusion_factor(),
            out.scan.polls,
            out.scan.class_passes
        );
    }
}
