//! End-to-end sharded-cluster serving benchmark: a real in-process
//! cluster (N shard servers + scatter-gather router over loopback TCP)
//! swept across (shards × fan-out s) cells by the closed-loop load
//! generator, plus a shard-pruning recall column — how often the
//! pruned fan-out reproduces the full fan-out top-1.
//!
//! Set `AMSEARCH_BENCH_JSON=BENCH_cluster_serving.json` to emit the
//! measurements as a machine-readable artifact, and `AMSEARCH_BENCH_MS`
//! to scale the per-cell request budget.

#[path = "harness_common.rs"]
#[allow(dead_code)] // helpers are shared; each target uses a subset
mod harness;

use std::time::Duration;

use amsearch::cluster::{ClusterConfig, ClusterHarness, ShardStrategy};
use amsearch::data::clustered::{clustered_workload, ClusteredSpec};
use amsearch::data::rng::Rng;
use amsearch::index::{AmIndex, IndexParams};
use amsearch::metrics::PruneRecall;
use amsearch::net::{loadgen, LoadGenConfig};
use harness::{budget, section, write_json_if_requested, Measurement};

fn main() {
    let mut rng = Rng::new(53);
    let (d, n, q, p) = (64usize, 8192usize, 32usize, 4usize);
    let spec = ClusteredSpec { dim: d, n_clusters: q, ..ClusteredSpec::sift_like() };
    let wl = clustered_workload(spec, n, 128, &mut rng);
    let params = IndexParams { n_classes: q, top_p: p, ..Default::default() };
    let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
    let queries: Vec<Vec<f32>> =
        (0..wl.queries.len()).map(|qi| wl.queries.get(qi).to_vec()).collect();
    let requests = (budget().as_millis() as usize * 10).max(200);

    section("sharded cluster serving (loadgen -> router -> top-s shards)");
    let mut all: Vec<Measurement> = Vec::new();
    for &n_shards in &[2usize, 4] {
        let cfg = ClusterConfig {
            n_shards,
            strategy: ShardStrategy::BalancedMembers,
            ..Default::default()
        };
        let cluster = ClusterHarness::launch(&index, "127.0.0.1:0", &cfg).unwrap();
        let addr = cluster.router_addr().to_string();
        println!(
            "cluster: n={n} d={d} q={q} shards={n_shards} at {addr} \
             (shard sizes: {:?})",
            (0..n_shards)
                .map(|si| cluster.router().table().shard_len(si))
                .collect::<Vec<_>>()
        );
        for s in 1..=n_shards {
            cluster.router().set_fan_out(s);
            let lg = LoadGenConfig {
                connections: 4,
                depth: 8,
                requests,
                top_p: 0,
                top_k: 1,
                connect_timeout: Duration::from_secs(10),
            };
            let report = loadgen::run(&addr, &queries, &lg).unwrap();
            // shard-pruning recall: pruned top-1 vs full-fan-out top-1
            // on the workload queries (s = N is identical by definition)
            let mut prune = PruneRecall::new();
            for query in queries.iter().take(64) {
                cluster.router().set_fan_out(s);
                let pruned = cluster.router().search(query.clone(), 0, 1).unwrap();
                cluster.router().set_fan_out(n_shards);
                let full = cluster.router().search(query.clone(), 0, 1).unwrap();
                prune.record(pruned.neighbor(), full.neighbor());
            }
            let m = Measurement {
                name: format!("cluster shards={n_shards} fanout={s}"),
                iters: report.requests,
                mean_ns: report.latency.mean_ns(),
                p50_ns: report.latency.quantile_ns(0.5) as f64,
                p95_ns: report.latency.quantile_ns(0.95) as f64,
            };
            m.report();
            println!(
                "  -> {:.0} qps, p99 {:.2}us, errors {}, prune-recall {:.3}",
                report.qps(),
                report.latency.quantile_ns(0.99) as f64 / 1e3,
                report.errors,
                prune.value()
            );
            all.push(m);
        }
        let rm = cluster.router().metrics();
        println!(
            "router: {} requests, mean fan-out {:.2}, end-to-end {} | \
             shard service {}",
            rm.requests,
            rm.fanout.mean_fanout(),
            rm.latency.summary(),
            rm.shard_service.summary()
        );
        cluster.shutdown();
    }
    write_json_if_requested(&all);
}
