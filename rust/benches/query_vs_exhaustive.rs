//! The paper's headline claim, as wall-clock: end-to-end AM-index query
//! vs exhaustive search, across database sizes and poll depths.  Prints
//! measured speedup next to the cost-model prediction
//! `(d²q + pkd) / (nd)` — shapes should agree within ~2x.

#[path = "harness_common.rs"]
#[allow(dead_code)] // helpers are shared; each target uses a subset
mod harness;

use amsearch::baseline::Exhaustive;
use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel, SparseSpec};
use amsearch::index::{AmIndex, IndexParams};
use amsearch::metrics::{CostModel, OpsCounter};
use amsearch::search::Metric;
use harness::{bench, budget, section};

fn main() {
    let mut rng = Rng::new(7);

    section("dense d=128: AM query vs exhaustive (wall-clock)");
    for &(n, q) in &[(16_384usize, 64usize), (65_536, 128)] {
        let wl = synthetic::dense_workload(128, n, 16, QueryModel::Exact, &mut rng);
        let params = IndexParams { n_classes: q, ..Default::default() };
        let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        let ex = Exhaustive::new(wl.base.clone(), Metric::SqL2);
        let k = n / q;

        let mut qi = 0usize;
        let m_ex = bench(&format!("exhaustive n={n}"), budget(), || {
            let mut ops = OpsCounter::new();
            let r = ex.query(wl.queries.get(qi % 16), &mut ops);
            std::hint::black_box(r);
            qi += 1;
        });
        m_ex.report();

        for p in [1usize, 4] {
            let mut qj = 0usize;
            let m_am = bench(&format!("am n={n} q={q} p={p}"), budget(), || {
                let mut ops = OpsCounter::new();
                let r = index.query(wl.queries.get(qj % 16), p, &mut ops);
                std::hint::black_box(r);
                qj += 1;
            });
            m_am.report();
            let model = CostModel {
                effective_dim: 128,
                q: q as u64,
                k: k as u64,
                n: n as u64,
            };
            println!(
                "  -> measured speedup {:.2}x | cost model predicts {:.2}x",
                m_ex.mean_ns / m_am.mean_ns,
                1.0 / model.relative(p as u64)
            );
        }
    }

    section("sparse d=128 c=8: the paper's strongest regime");
    {
        let (n, q) = (65_536usize, 64usize);
        let wl = synthetic::sparse_workload(
            SparseSpec { dim: 128, ones: 8.0 },
            n,
            16,
            QueryModel::Exact,
            &mut rng,
        );
        let params = IndexParams { n_classes: q, ..Default::default() };
        let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        let ex = Exhaustive::new(wl.base.clone(), Metric::SqL2);
        let mut qi = 0usize;
        let m_ex = bench("exhaustive (sparse)", budget(), || {
            let mut ops = OpsCounter::new();
            std::hint::black_box(ex.query(wl.queries.get(qi % 16), &mut ops));
            qi += 1;
        });
        m_ex.report();
        let mut qj = 0usize;
        let m_am = bench("am p=1 (sparse, c² scoring)", budget(), || {
            let mut ops = OpsCounter::new();
            std::hint::black_box(index.query(wl.queries.get(qj % 16), 1, &mut ops));
            qj += 1;
        });
        m_am.report();
        let model =
            CostModel { effective_dim: 8, q: q as u64, k: (n / q) as u64, n: n as u64 };
        println!(
            "  -> measured speedup {:.2}x | cost model predicts {:.2}x",
            m_ex.mean_ns / m_am.mean_ns,
            1.0 / model.relative(1)
        );
    }

    section("index build cost (amortized once per corpus)");
    for &(n, q) in &[(16_384usize, 64usize)] {
        let wl = synthetic::dense_workload(128, n, 1, QueryModel::Exact, &mut rng);
        let params = IndexParams { n_classes: q, ..Default::default() };
        let t = std::time::Instant::now();
        let index = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        println!(
            "build n={n} q={q} d=128: {:.2}s ({} classes, {} MB bank)",
            t.elapsed().as_secs_f64(),
            index.bank().n_classes(),
            index.bank().stacked().len() * 4 / 1_000_000
        );
    }
}
