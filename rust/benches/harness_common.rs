//! Minimal benchmarking harness shared by all bench targets (the offline
//! build has no criterion).  Criterion-style: warmup, then timed
//! iterations until a wall-clock budget is spent, reporting mean /
//! p50 / p95 per-iteration time and optional throughput.

use std::time::{Duration, Instant};

/// One benchmark measurement.
pub struct Measurement {
    /// Benchmark id.
    pub name: String,
    /// Number of timed iterations.
    pub iters: u64,
    /// Mean ns/iter.
    pub mean_ns: f64,
    /// Median ns/iter.
    pub p50_ns: f64,
    /// p95 ns/iter.
    pub p95_ns: f64,
}

impl Measurement {
    /// Pretty one-line report, criterion-like.
    pub fn report(&self) {
        println!(
            "{:<44} {:>12} iters  mean {:>12}  p50 {:>12}  p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
        );
    }

    /// Report with an ops-derived throughput column.
    #[allow(dead_code)] // each bench target includes this module à la carte
    pub fn report_throughput(&self, unit: &str, per_iter: f64) {
        let per_sec = per_iter / (self.mean_ns / 1e9);
        println!(
            "{:<44} {:>12} iters  mean {:>12}  p50 {:>12}  {:>14.3} {unit}/s",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            per_sec,
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` repeatedly for ~`budget` after one warmup call.
pub fn bench(name: &str, budget: Duration, mut f: impl FnMut()) -> Measurement {
    f(); // warmup + lazy init
    let mut samples: Vec<u64> = Vec::new();
    let started = Instant::now();
    while started.elapsed() < budget || samples.len() < 5 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as u64);
        if samples.len() >= 100_000 {
            break;
        }
    }
    samples.sort_unstable();
    let iters = samples.len() as u64;
    let mean_ns = samples.iter().sum::<u64>() as f64 / iters as f64;
    let p50_ns = samples[samples.len() / 2] as f64;
    let p95_ns = samples[(samples.len() * 95 / 100).min(samples.len() - 1)] as f64;
    Measurement { name: name.to_string(), iters, mean_ns, p50_ns, p95_ns }
}

/// Standard per-bench budget, overridable via `AMSEARCH_BENCH_MS`.
pub fn budget() -> Duration {
    let ms = std::env::var("AMSEARCH_BENCH_MS")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(400);
    Duration::from_millis(ms)
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Write measurements as a JSON array (one object per measurement) to
/// the path named by the `AMSEARCH_BENCH_JSON` env var; no-op when the
/// variable is unset.  This is how CI captures a bench trajectory as an
/// uploadable artifact without parsing console output.
#[allow(dead_code)] // each bench target includes this module à la carte
pub fn write_json_if_requested(measurements: &[Measurement]) {
    let Ok(path) = std::env::var("AMSEARCH_BENCH_JSON") else {
        return;
    };
    let mut out = String::from("[\n");
    for (i, m) in measurements.iter().enumerate() {
        let sep = if i + 1 == measurements.len() { "" } else { "," };
        out.push_str(&format!(
            "  {{\"name\": {:?}, \"iters\": {}, \"mean_ns\": {:.1}, \
             \"p50_ns\": {:.1}, \"p95_ns\": {:.1}}}{sep}\n",
            m.name, m.iters, m.mean_ns, m.p50_ns, m.p95_ns
        ));
    }
    out.push_str("]\n");
    match std::fs::write(&path, out) {
        Ok(()) => println!("\nwrote {} measurements to {path}", measurements.len()),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
