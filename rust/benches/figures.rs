//! Figure-harness benchmarks: times each paper-figure driver at reduced
//! scale.  This is both a perf-regression guard for the Monte-Carlo
//! machinery and the `cargo bench` entry point that exercises every
//! table/figure code path (full-scale data comes from `amsearch eval`).

#[path = "harness_common.rs"]
#[allow(dead_code)] // helpers are shared; each target uses a subset
mod harness;

use amsearch::eval::{run_figure, EvalOptions, ALL_FIGURES};
use harness::section;

fn main() {
    let scale = std::env::var("AMSEARCH_FIG_SCALE")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(0.02);
    let opts = EvalOptions { scale, seed: 42 };
    section(&format!("paper figure harnesses at scale={scale}"));
    for id in ALL_FIGURES {
        let t = std::time::Instant::now();
        match run_figure(id, &opts) {
            Ok(fig) => {
                let points: usize = fig.series.iter().map(|s| s.points.len()).sum();
                println!(
                    "{:<24} {:>2} series {:>4} points   {:>9.2}s",
                    fig.id,
                    fig.series.len(),
                    points,
                    t.elapsed().as_secs_f64()
                );
            }
            Err(e) => println!("{id:<24} ERROR: {e}"),
        }
    }
}
