//! Scoring-path benchmarks: the `d²·q` bilinear form (dense) and the
//! `c²·q` support path (sparse) across realistic shapes, with effective
//! memory bandwidth so the result can be compared against the machine's
//! roofline (the scorer is bandwidth-bound: each f32 of the `[q,d,d]`
//! bank is read once per batch).

#[path = "harness_common.rs"]
#[allow(dead_code)] // helpers are shared; each target uses a subset
mod harness;

use amsearch::data::rng::Rng;
use amsearch::memory::score::{score_batch, score_batch_support};
use amsearch::search::Kernels;
use harness::{bench, budget, section};

fn random_bank(rng: &mut Rng, q: usize, d: usize) -> Vec<f32> {
    (0..q * d * d).map(|_| rng.normal() as f32).collect()
}

fn main() {
    let mut rng = Rng::new(42);
    let kernels = Kernels::select();

    section("dense bilinear scoring: scores = x^T W_i x  (native scorer)");
    for &(d, q, b) in &[
        (64usize, 32usize, 1usize),
        (64, 32, 8),
        (128, 64, 1),
        (128, 64, 8),
        (128, 256, 8),
        (960, 20, 4),
    ] {
        let bank = random_bank(&mut rng, q, d);
        let queries: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
        let m = bench(
            &format!("score_batch d={d} q={q} B={b}"),
            budget(),
            || {
                let s = score_batch(&bank, &queries, d, q, kernels);
                std::hint::black_box(s);
            },
        );
        // bytes touched per iteration: the whole bank once (batch-fused)
        let gb = (q * d * d * 4) as f64 / 1e9;
        m.report_throughput("GB(bank)", gb);
    }

    section("sparse support scoring: c²·q path");
    for &(d, q, c, b) in
        &[(128usize, 64usize, 8usize, 8usize), (369, 40, 33, 8), (128, 256, 8, 8)]
    {
        let bank = random_bank(&mut rng, q, d);
        let supports: Vec<Vec<u32>> = (0..b)
            .map(|_| {
                let mut s: Vec<u32> =
                    (0..c).map(|_| rng.below(d as u64) as u32).collect();
                s.sort_unstable();
                s.dedup();
                s
            })
            .collect();
        let m = bench(
            &format!("score_support d={d} q={q} c={c} B={b}"),
            budget(),
            || {
                let s = score_batch_support(&bank, &supports, d, q);
                std::hint::black_box(s);
            },
        );
        m.report_throughput("score", (q * b) as f64);
    }

    section("speedup check: support path vs dense path on sparse queries");
    {
        let (d, q, c, b) = (369usize, 40usize, 33usize, 8usize);
        let bank = random_bank(&mut rng, q, d);
        let mut dense_queries = vec![0f32; b * d];
        let mut supports = Vec::new();
        for bi in 0..b {
            let mut s = Vec::new();
            for _ in 0..c {
                let j = rng.below(d as u64) as usize;
                if dense_queries[bi * d + j] == 0.0 {
                    dense_queries[bi * d + j] = 1.0;
                    s.push(j as u32);
                }
            }
            s.sort_unstable();
            supports.push(s);
        }
        let md = bench("dense path (d²q)", budget(), || {
            std::hint::black_box(score_batch(&bank, &dense_queries, d, q, kernels));
        });
        let ms = bench("support path (c²q)", budget(), || {
            std::hint::black_box(score_batch_support(&bank, &supports, d, q));
        });
        md.report();
        ms.report();
        println!(
            "support-path speedup: {:.1}x (cost model predicts ~{:.1}x)",
            md.mean_ns / ms.mean_ns,
            (d * d) as f64 / (c * c) as f64
        );
    }
}
