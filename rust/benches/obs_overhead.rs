//! Observability overhead benchmark: the same coordinator round-trip
//! with tracing disabled vs every request traced into a discarding
//! sink.  CI runs this with `AMSEARCH_BENCH_JSON` and feeds the two
//! cells to `benchcmp --pair` to enforce the ≤ 2% overhead budget —
//! tracing that is off must cost nothing, and tracing that is on must
//! stay in the noise.

#[path = "harness_common.rs"]
#[allow(dead_code)] // helpers are shared; each target uses a subset
mod harness;

use std::sync::Arc;

use amsearch::coordinator::{CoordinatorConfig, EngineFactory, SearchServer};
use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel};
use amsearch::index::{AmIndex, IndexParams};
use amsearch::obs::TraceSink;
use amsearch::runtime::Backend;
use harness::{bench, budget, section, write_json_if_requested};

fn main() {
    let mut rng = Rng::new(17);
    let wl = synthetic::dense_workload(64, 4_096, 64, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: 16, top_p: 2, ..Default::default() };
    let index = Arc::new(AmIndex::build(wl.base.clone(), params, &mut rng).unwrap());
    let config = CoordinatorConfig {
        max_batch: 1,
        max_wait_us: 0,
        workers: 1,
        queue_depth: 16,
    };
    let factory = || EngineFactory {
        index: index.clone(),
        backend: Backend::Native,
        artifacts_dir: None,
    };

    section("coordinator round-trip: tracing off vs every request traced");
    let mut measurements = Vec::new();

    let untraced = Arc::new(SearchServer::start(factory(), config).unwrap());
    let mut qi = 0usize;
    let m = bench("obs/untraced", budget(), || {
        let q = wl.queries.get(qi % 64).to_vec();
        std::hint::black_box(untraced.search(q, 0, 0).unwrap());
        qi += 1;
    });
    m.report();
    measurements.push(m);
    untraced.shutdown();

    // sample_every = 1: every request builds a span record and writes a
    // JSON line (into a discarding sink, so this bounds the CPU cost of
    // tracing itself, not the disk)
    let sink = TraceSink::new(Box::new(std::io::sink()), 1, 0);
    let traced = Arc::new(
        SearchServer::start_traced(factory(), config, Some(sink.clone())).unwrap(),
    );
    let mut qj = 0usize;
    let m = bench("obs/traced", budget(), || {
        let q = wl.queries.get(qj % 64).to_vec();
        std::hint::black_box(traced.search(q, 0, 0).unwrap());
        qj += 1;
    });
    m.report();
    assert!(sink.emitted() > 0, "traced cell must actually emit records");
    println!("  trace records emitted: {}", sink.emitted());
    let (untraced_ns, traced_ns) = (measurements[0].mean_ns, m.mean_ns);
    println!(
        "  overhead: {:+.2}% mean ns/request",
        100.0 * (traced_ns - untraced_ns) / untraced_ns
    );
    measurements.push(m);
    traced.shutdown();

    write_json_if_requested(&measurements);
}
