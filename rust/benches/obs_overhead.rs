//! Observability overhead benchmark: the same coordinator round-trip
//! with tracing disabled vs every request traced into a discarding
//! sink, and with quality sampling disabled vs every request
//! shadow-verified by the off-path exact-scan worker.  CI runs this
//! with `AMSEARCH_BENCH_JSON` and feeds each pair to `benchcmp --pair`
//! to enforce the ≤ 2% overhead budget — observability that is off must
//! cost nothing, and observability that is on must stay in the noise on
//! the serving path (the shadow worker burns its own core, not the
//! request's).

#[path = "harness_common.rs"]
#[allow(dead_code)] // helpers are shared; each target uses a subset
mod harness;

use std::sync::Arc;

use amsearch::coordinator::{CoordinatorConfig, EngineFactory, SearchServer};
use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel};
use amsearch::index::{AmIndex, IndexParams};
use amsearch::obs::TraceSink;
use amsearch::runtime::Backend;
use harness::{bench, budget, section, write_json_if_requested};

fn main() {
    let mut rng = Rng::new(17);
    let wl = synthetic::dense_workload(64, 4_096, 64, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: 16, top_p: 2, ..Default::default() };
    let index = Arc::new(AmIndex::build(wl.base.clone(), params, &mut rng).unwrap());
    let config = CoordinatorConfig {
        max_batch: 1,
        max_wait_us: 0,
        workers: 1,
        queue_depth: 16,
        quality_sample: 0,
    };
    let factory = || EngineFactory {
        index: index.clone(),
        backend: Backend::Native,
        artifacts_dir: None,
    };

    section("coordinator round-trip: tracing off vs every request traced");
    let mut measurements = Vec::new();

    let untraced = Arc::new(SearchServer::start(factory(), config).unwrap());
    let mut qi = 0usize;
    let m = bench("obs/untraced", budget(), || {
        let q = wl.queries.get(qi % 64).to_vec();
        std::hint::black_box(untraced.search(q, 0, 0).unwrap());
        qi += 1;
    });
    m.report();
    measurements.push(m);
    untraced.shutdown();

    // sample_every = 1: every request builds a span record and writes a
    // JSON line (into a discarding sink, so this bounds the CPU cost of
    // tracing itself, not the disk)
    let sink = TraceSink::new(Box::new(std::io::sink()), 1, 0);
    let traced = Arc::new(
        SearchServer::start_traced(factory(), config, Some(sink.clone())).unwrap(),
    );
    let mut qj = 0usize;
    let m = bench("obs/traced", budget(), || {
        let q = wl.queries.get(qj % 64).to_vec();
        std::hint::black_box(traced.search(q, 0, 0).unwrap());
        qj += 1;
    });
    m.report();
    assert!(sink.emitted() > 0, "traced cell must actually emit records");
    println!("  trace records emitted: {}", sink.emitted());
    let (untraced_ns, traced_ns) = (measurements[0].mean_ns, m.mean_ns);
    println!(
        "  overhead: {:+.2}% mean ns/request",
        100.0 * (traced_ns - untraced_ns) / untraced_ns
    );
    measurements.push(m);
    traced.shutdown();

    section("coordinator round-trip: quality sampling off vs every request shadow-verified");
    // a fresh off cell measured back-to-back with its pair, so the gate
    // compares cells from the same thermal/cache regime
    let quality_off = Arc::new(SearchServer::start(factory(), config).unwrap());
    let mut qa = 0usize;
    let m = bench("obs/quality_off", budget(), || {
        let q = wl.queries.get(qa % 64).to_vec();
        std::hint::black_box(quality_off.search(q, 0, 0).unwrap());
        qa += 1;
    });
    m.report();
    measurements.push(m);
    quality_off.shutdown();

    // quality_sample = 1: every request's inputs are cloned onto the
    // bounded shadow queue; the exact scan itself runs on the dedicated
    // worker, so the serving path pays only the clone + push
    let quality_cfg = CoordinatorConfig { quality_sample: 1, ..config };
    let sampled = Arc::new(SearchServer::start(factory(), quality_cfg).unwrap());
    let mut qb = 0usize;
    let m = bench("obs/quality_sampled", budget(), || {
        let q = wl.queries.get(qb % 64).to_vec();
        std::hint::black_box(sampled.search(q, 0, 0).unwrap());
        qb += 1;
    });
    m.report();
    let off_ns = measurements.last().map(|p| p.mean_ns).unwrap_or(0.0);
    println!(
        "  overhead: {:+.2}% mean ns/request",
        100.0 * (m.mean_ns - off_ns) / off_ns
    );
    measurements.push(m);
    sampled.shutdown(); // drains the shadow queue before the assert
    let quality = sampled.metrics().quality;
    assert!(
        quality.samples > 0,
        "sampled cell must actually shadow-verify requests"
    );
    println!(
        "  shadow samples: {} (dropped {}, recall {:.4})",
        quality.samples,
        quality.dropped,
        quality.recall()
    );

    write_json_if_requested(&measurements);
}
