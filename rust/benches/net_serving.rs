//! End-to-end TCP serving benchmark: a full in-process stack (index →
//! coordinator → TCP front door on an ephemeral localhost port) driven
//! by the closed-loop load generator across (connections × depth)
//! cells.  The reported latency is the *network* figure of merit —
//! submit-to-response over a real socket, through framing, the bounded
//! request queue, dynamic batching, and the class-grouped scan.
//!
//! Set `AMSEARCH_BENCH_JSON=BENCH_net_serving.json` to emit the
//! measurements as a machine-readable artifact, and `AMSEARCH_BENCH_MS`
//! to scale the per-cell request budget (requests = 20 × budget-ms,
//! min 200).

#[path = "harness_common.rs"]
#[allow(dead_code)] // helpers are shared; each target uses a subset
mod harness;

use std::sync::Arc;
use std::time::Duration;

use amsearch::coordinator::{CoordinatorConfig, EngineFactory, SearchServer};
use amsearch::data::clustered::{clustered_workload, ClusteredSpec};
use amsearch::data::rng::Rng;
use amsearch::index::{AmIndex, IndexParams};
use amsearch::net::{loadgen, LoadGenConfig, NetConfig, NetServer};
use amsearch::runtime::Backend;
use harness::{budget, section, write_json_if_requested, Measurement};

fn main() {
    let mut rng = Rng::new(47);
    let (d, n, q, p) = (128usize, 16_384usize, 64usize, 4usize);
    let spec = ClusteredSpec { dim: d, n_clusters: q, ..ClusteredSpec::sift_like() };
    let wl = clustered_workload(spec, n, 128, &mut rng);
    let params = IndexParams { n_classes: q, top_p: p, ..Default::default() };
    let index = Arc::new(AmIndex::build(wl.base.clone(), params, &mut rng).unwrap());
    let factory =
        EngineFactory { index: index.clone(), backend: Backend::Native, artifacts_dir: None };
    let server =
        Arc::new(SearchServer::start(factory, CoordinatorConfig::default()).unwrap());
    let net =
        NetServer::bind(server.clone(), "127.0.0.1:0", NetConfig::default()).unwrap();
    let addr = net.local_addr().to_string();
    println!("stack: clustered n={n} d={d} q={q} p={p}, TCP at {addr}");

    let queries: Vec<Vec<f32>> =
        (0..wl.queries.len()).map(|qi| wl.queries.get(qi).to_vec()).collect();
    // scale request count with the shared time budget so CI smoke runs
    // stay ~seconds while local runs measure properly
    let requests = (budget().as_millis() as usize * 20).max(200);

    section("closed-loop TCP serving (submit -> response over a real socket)");
    let mut all: Vec<Measurement> = Vec::new();
    for &(connections, depth) in &[(1usize, 1usize), (4, 8), (8, 16)] {
        let cfg = LoadGenConfig {
            connections,
            depth,
            requests,
            top_p: 0,
            top_k: 1,
            connect_timeout: Duration::from_secs(10),
        };
        let report = loadgen::run(&addr, &queries, &cfg).unwrap();
        let m = Measurement {
            name: format!("tcp loadgen  conns={connections:<2} depth={depth:<3}"),
            iters: report.requests,
            mean_ns: report.latency.mean_ns(),
            p50_ns: report.latency.quantile_ns(0.5) as f64,
            p95_ns: report.latency.quantile_ns(0.95) as f64,
        };
        m.report();
        println!(
            "  -> {:.0} qps, p99 {:.2}us, errors {}",
            report.qps(),
            report.latency.quantile_ns(0.99) as f64 / 1e3,
            report.errors
        );
        all.push(m);
    }
    let m = server.metrics();
    println!(
        "server: batches={} mean_batch={:.2} scan_fusion={:.2}",
        m.batches,
        m.mean_batch_size(),
        m.scan.fusion_factor()
    );
    net.shutdown();
    server.shutdown();
    write_json_if_requested(&all);
}
