//! Coordinator benchmarks: serving throughput and the batching overhead
//! relative to calling the engine directly (the coordinator must not be
//! the bottleneck — DESIGN.md §8 budgets it < 10% of query cost at B=8).

#[path = "harness_common.rs"]
#[allow(dead_code)] // helpers are shared; each target uses a subset
mod harness;

use std::sync::Arc;
use std::time::Instant;

use amsearch::coordinator::{CoordinatorConfig, Engine, EngineFactory, SearchServer};
use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel};
use amsearch::index::{AmIndex, IndexParams};
use amsearch::runtime::Backend;
use amsearch::util::concurrent_map;
use harness::{bench, budget, section};

fn main() {
    let mut rng = Rng::new(11);
    let wl = synthetic::dense_workload(128, 16_384, 64, QueryModel::Exact, &mut rng);
    let params = IndexParams { n_classes: 64, top_p: 2, ..Default::default() };
    let index = Arc::new(AmIndex::build(wl.base.clone(), params, &mut rng).unwrap());

    section("engine direct (no coordinator) — the service-time floor");
    let engine = Engine::native(index.clone()).unwrap();
    let mut qi = 0usize;
    let m_direct1 = bench("engine.serve_batch B=1", budget(), || {
        let q = wl.queries.get(qi % 64);
        std::hint::black_box(engine.serve_batch(&[(q, 2usize, 1usize)]).unwrap());
        qi += 1;
    });
    m_direct1.report();
    let queries8: Vec<(&[f32], usize, usize)> =
        (0..8).map(|i| (wl.queries.get(i), 2usize, 1usize)).collect();
    let m_direct8 = bench("engine.serve_batch B=8", budget(), || {
        std::hint::black_box(engine.serve_batch(&queries8).unwrap());
    });
    m_direct8.report();
    println!(
        "  per-request at B=8: {} (batch amortization {:.2}x)",
        format_ns(m_direct8.mean_ns / 8.0),
        m_direct1.mean_ns / (m_direct8.mean_ns / 8.0)
    );

    section("full coordinator: throughput under concurrent load");
    for &(workers, max_batch, clients) in
        &[(1usize, 1usize, 4usize), (1, 8, 16), (2, 8, 16)]
    {
        let factory = EngineFactory {
            index: index.clone(),
            backend: Backend::Native,
            artifacts_dir: None,
        };
        let config = CoordinatorConfig {
            max_batch,
            max_wait_us: 200,
            workers,
            queue_depth: 256,
            quality_sample: 0,
        };
        let server = Arc::new(SearchServer::start(factory, config).unwrap());
        let total = 2_000usize;
        let t = Instant::now();
        concurrent_map(total, clients, |i| {
            let q = wl.queries.get(i % 64).to_vec();
            server.search(q, 0, 0).unwrap()
        });
        let secs = t.elapsed().as_secs_f64();
        let m = server.metrics();
        println!(
            "workers={workers} max_batch={max_batch} clients={clients}: \
             {:>8.0} qps  mean_batch={:.2}  p50={} p95={}",
            total as f64 / secs,
            m.mean_batch_size(),
            format_ns(m.latency.quantile_ns(0.5) as f64),
            format_ns(m.latency.quantile_ns(0.95) as f64),
        );
        server.shutdown();
    }

    section("coordinator overhead vs direct engine call");
    {
        let factory = EngineFactory {
            index: index.clone(),
            backend: Backend::Native,
            artifacts_dir: None,
        };
        let config = CoordinatorConfig {
            max_batch: 1,
            max_wait_us: 0,
            workers: 1,
            queue_depth: 16,
            quality_sample: 0,
        };
        let server = Arc::new(SearchServer::start(factory, config).unwrap());
        let mut qj = 0usize;
        let m_coord = bench("coordinator round-trip B=1", budget(), || {
            let q = wl.queries.get(qj % 64).to_vec();
            std::hint::black_box(server.search(q, 0, 0).unwrap());
            qj += 1;
        });
        m_coord.report();
        let overhead = m_coord.mean_ns - m_direct1.mean_ns;
        println!(
            "  overhead per request: {} ({:.1}% of service time)",
            format_ns(overhead.max(0.0)),
            100.0 * overhead.max(0.0) / m_direct1.mean_ns
        );
        server.shutdown();
    }
}

fn format_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0}ns")
    } else if ns < 1_000_000.0 {
        format!("{:.1}us", ns / 1e3)
    } else {
        format!("{:.2}ms", ns / 1e6)
    }
}
