//! Paged vs resident exact scan: wall-clock and I/O accounting for the
//! disk-resident store (`store::PagedStore`), sweeping dataset sizes
//! past a simulated RAM budget so the extent cache goes from
//! everything-fits to actively evicting.
//!
//! Beyond latency rows, this target emits the paged store's byte
//! accounting as extra measurement rows so `BENCH_store.json` captures
//! the I/O-pruning claim: for those rows `mean_ns` carries a **byte
//! count, not a time** (the row name says which; the shared JSON schema
//! has no units field).  The headline invariant — per-query bytes read
//! off disk stays far below what a resident store keeps in RAM — is
//! asserted here, not just reported.

#[path = "harness_common.rs"]
#[allow(dead_code)] // helpers are shared; each target uses a subset
mod harness;

use amsearch::data::rng::Rng;
use amsearch::data::synthetic::{self, QueryModel};
use amsearch::index::persist;
use amsearch::index::{AmIndex, IndexParams};
use amsearch::metrics::OpsCounter;
use harness::{bench, budget, section, write_json_if_requested, Measurement};

/// The simulated RAM budget for the extent cache: small datasets fit
/// entirely, the larger sweep points overflow it and must evict.
const CACHE_BYTES: u64 = 4 * 1024 * 1024;

/// A byte counter disguised as a measurement row (`mean_ns` = bytes).
fn byte_row(name: String, bytes: f64) -> Measurement {
    Measurement { name, iters: 1, mean_ns: bytes, p50_ns: bytes, p95_ns: bytes }
}

fn main() {
    let mut rng = Rng::new(41);
    let dir = std::env::temp_dir().join(format!("amsearch_bench_paged_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create bench scratch dir");
    let mut rows: Vec<Measurement> = Vec::new();

    section("paged vs resident exact scan (d=64, q=64, default fan-out)");
    for &n in &[8_192usize, 32_768, 65_536] {
        let d = 64usize;
        let wl = synthetic::dense_workload(d, n, 16, QueryModel::Exact, &mut rng);
        let params = IndexParams { n_classes: 64, top_p: 4, top_k: 10, ..Default::default() };
        let built = AmIndex::build(wl.base.clone(), params, &mut rng).unwrap();
        let path = dir.join(format!("bench_{n}.amidx"));
        persist::save(&built, &path).unwrap();
        let resident = persist::load(&path).unwrap();
        let paged = persist::load_paged(&path, CACHE_BYTES).unwrap();
        let data_bytes = (n * d * 4) as f64;

        // the paged full path must be bitwise-equal to the resident scan
        for qi in 0..8usize {
            let x = wl.queries.get(qi);
            let mut ops = OpsCounter::new();
            let a = resident.query_default(x, &mut ops);
            let mut ops = OpsCounter::new();
            let b = paged.query_default(x, &mut ops);
            assert_eq!(a.neighbors.len(), b.neighbors.len(), "n={n} q{qi}");
            for (na, nb) in a.neighbors.iter().zip(&b.neighbors) {
                assert_eq!(na.id, nb.id, "n={n} q{qi}");
                assert_eq!(
                    na.distance.to_bits(),
                    nb.distance.to_bits(),
                    "n={n} q{qi}: paged rerank must be bitwise-equal"
                );
            }
        }
        assert!(paged.store_error().is_none(), "paged store poisoned");

        let mut qi = 0usize;
        let m = bench(&format!("resident query n={n}"), budget(), || {
            let mut ops = OpsCounter::new();
            std::hint::black_box(resident.query_default(wl.queries.get(qi % 16), &mut ops));
            qi += 1;
        });
        m.report();
        rows.push(m);

        let before = paged.store_stats();
        let mut qj = 0usize;
        let m = bench(&format!("paged query n={n}"), budget(), || {
            let mut ops = OpsCounter::new();
            std::hint::black_box(paged.query_default(wl.queries.get(qj % 16), &mut ops));
            qj += 1;
        });
        m.report();
        let after = paged.store_stats();
        let queries = m.iters.max(1) as f64;
        rows.push(m);

        let read_per_query = after.bytes_read.saturating_sub(before.bytes_read) as f64 / queries;
        let hits = after.cache_hits.saturating_sub(before.cache_hits) as f64;
        let misses = after.cache_misses.saturating_sub(before.cache_misses) as f64;
        let hit_rate = if hits + misses > 0.0 { hits / (hits + misses) } else { 0.0 };
        println!(
            "  -> store: {:.1} KB read/query of {:.1} MB on disk, cache hit {:.1}%, \
             {:.1} of {:.1} MB cached, {} evictions",
            read_per_query / 1e3,
            after.bytes_disk as f64 / 1e6,
            hit_rate * 100.0,
            after.bytes_resident as f64 / 1e6,
            after.cache_budget as f64 / 1e6,
            after.cache_evictions
        );
        // I/O pruning: a polled-class read pattern must not stream the
        // whole file per query the way a resident scan streams RAM
        assert!(
            read_per_query < data_bytes,
            "n={n}: paged scan read {read_per_query} bytes/query over a {data_bytes}-byte dataset"
        );
        rows.push(byte_row(format!("paged n={n} bytes_read/query [bytes]"), read_per_query));
        rows.push(byte_row(
            format!("paged n={n} bytes_resident [bytes]"),
            after.bytes_resident as f64,
        ));
        rows.push(byte_row(format!("paged n={n} bytes_disk [bytes]"), after.bytes_disk as f64));
        rows.push(byte_row(format!("resident n={n} bytes_resident [bytes]"), data_bytes));
    }

    section("paged exhaustive reference scan (class-major full read)");
    {
        let n = 32_768usize;
        let path = dir.join(format!("bench_{n}.amidx"));
        let paged = persist::load_paged(&path, CACHE_BYTES).unwrap();
        let wl = synthetic::dense_workload(64, 4, 4, QueryModel::Exact, &mut rng);
        let mut qi = 0usize;
        let m = bench(&format!("paged exhaustive_exact n={n}"), budget(), || {
            std::hint::black_box(paged.exhaustive_exact(wl.queries.get(qi % 4), 10));
            qi += 1;
        });
        m.report();
        rows.push(m);
    }

    write_json_if_requested(&rows);
    let _ = std::fs::remove_dir_all(&dir);
}
