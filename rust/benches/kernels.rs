//! Distance-kernel benchmarks: per-backend ns/distance and effective
//! GB/s for every kernel family (f32 squared-L2 / dot, integer-domain
//! SQ8, gather-free ADC, hamming) plus the cache-blocked batch scan vs
//! its unblocked shape — the measured-performance program behind
//! `BENCH_kernels.json`.
//!
//! Cell names are stable identifiers (`kern f32 d=64 sse2`, `scan f32
//! d=128 B=8 sse2 blocked`, ...): `tools/benchcmp` joins fresh runs
//! against the committed baseline by exact name, so renaming a cell is
//! a baseline change, not a cosmetic edit.
//!
//! The JSON written to `AMSEARCH_BENCH_JSON` carries provenance
//! (`meta.harness`, `meta.cpu`): benchcmp refuses to hard-fail across
//! differing provenance, so numbers measured on one machine never gate
//! another.

#[path = "harness_common.rs"]
#[allow(dead_code)] // helpers are shared; each target uses a subset
mod harness;

use amsearch::data::rng::Rng;
use amsearch::search::{Backend, Kernels};
use harness::{bench, budget, section, Measurement};

/// One JSON row: the harness measurement plus the derived per-distance
/// and bandwidth columns benchcmp compares on.
struct Cell {
    m: Measurement,
    /// Nanoseconds per single distance evaluation.
    ns_per_distance: f64,
    /// Effective bandwidth over the bytes the kernel actually reads.
    gbps: f64,
}

/// Time `f` (which evaluates `dists` distances reading `bytes` bytes
/// per iteration) and derive the comparison columns.
fn cell(name: &str, dists: usize, bytes: usize, f: impl FnMut()) -> Cell {
    let m = bench(name, budget(), f);
    let ns_per_distance = m.mean_ns / dists as f64;
    let gbps = bytes as f64 / m.mean_ns;
    println!("{name:<40} {ns_per_distance:>8.2} ns/dist  {gbps:>7.2} GB/s");
    Cell { m, ns_per_distance, gbps }
}

/// The backends worth measuring on this host (scalar always; SIMD when
/// available).
fn backends() -> Vec<(Kernels, &'static str)> {
    [Backend::Scalar, Backend::Sse2, Backend::Avx2, Backend::Neon]
        .into_iter()
        .filter_map(|b| Kernels::with_backend(b).map(|k| (k, b.name())))
        .collect()
}

fn random_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn main() {
    let mut rng = Rng::new(7);
    let mut cells: Vec<Cell> = Vec::new();
    // enough rows that a scan iteration is measurable, few enough that
    // single-row kernels stay cache-resident (latency, not DRAM)
    const ROWS: usize = 256;

    section("f32 squared-L2, one row at a time (bitwise-pinned fold order)");
    for &d in &[16usize, 64, 128, 256] {
        let data = random_vec(&mut rng, ROWS * d);
        let x = random_vec(&mut rng, d);
        for (k, tag) in backends() {
            cells.push(cell(
                &format!("kern f32 d={d} {tag}"),
                ROWS,
                ROWS * d * 4,
                || {
                    let mut acc = 0f32;
                    for row in data.chunks_exact(d) {
                        acc += k.sq_l2(&x, row);
                    }
                    std::hint::black_box(acc);
                },
            ));
        }
    }

    section("f32 dot (scoring-path shape)");
    for &d in &[64usize, 128, 256] {
        let data = random_vec(&mut rng, ROWS * d);
        let x = random_vec(&mut rng, d);
        for (k, tag) in backends() {
            cells.push(cell(
                &format!("kern dot d={d} {tag}"),
                ROWS,
                ROWS * d * 4,
                || {
                    let mut acc = 0f32;
                    for row in data.chunks_exact(d) {
                        acc += k.dot(&x, row);
                    }
                    std::hint::black_box(acc);
                },
            ));
        }
    }

    section("SQ8 integer-domain distance over u8 codes");
    for &d in &[64usize, 128, 256] {
        let codes: Vec<u8> =
            (0..ROWS * d).map(|_| rng.below(256) as u8).collect();
        let qcode: Vec<u8> = (0..d).map(|_| rng.below(256) as u8).collect();
        let step2: Vec<f32> =
            (0..d).map(|_| rng.uniform() as f32 * 0.01 + 1e-4).collect();
        for (k, tag) in backends() {
            cells.push(cell(
                &format!("kern sq8 d={d} {tag}"),
                ROWS,
                ROWS * d,
                || {
                    let mut acc = 0f32;
                    for code in codes.chunks_exact(d) {
                        acc += k.sq8(&qcode, code, &step2);
                    }
                    std::hint::black_box(acc);
                },
            ));
        }
    }

    section("ADC table lookups over padded pow2 rows");
    for &(m, c) in &[(8usize, 16usize), (16, 16), (32, 16), (8, 256), (16, 256), (32, 256)] {
        let shift = (c as u32).next_power_of_two().trailing_zeros();
        let lut = random_vec(&mut rng, m << shift);
        let codes: Vec<u8> =
            (0..ROWS * m).map(|_| rng.below(c as u64) as u8).collect();
        for (k, tag) in backends() {
            cells.push(cell(
                &format!("kern adc m={m} c={c} {tag}"),
                ROWS,
                ROWS * m,
                || {
                    let mut acc = 0f32;
                    for code in codes.chunks_exact(m) {
                        acc += k.adc(&lut, shift, code);
                    }
                    std::hint::black_box(acc);
                },
            ));
        }
    }

    section("hamming over f32 lanes (binary sparse data)");
    for &d in &[128usize, 1024] {
        let a: Vec<f32> =
            (0..d).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let data: Vec<f32> = (0..ROWS * d)
            .map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 })
            .collect();
        for (k, tag) in backends() {
            cells.push(cell(
                &format!("kern hamming d={d} {tag}"),
                ROWS,
                ROWS * d * 4,
                || {
                    let mut acc = 0u32;
                    for row in data.chunks_exact(d) {
                        acc = acc.wrapping_add(k.hamming(&a, row));
                    }
                    std::hint::black_box(acc);
                },
            ));
        }
    }

    section("cache-blocked batch scan vs unblocked (class-major, query-fused)");
    {
        let d = 128usize;
        let n = 4096usize; // 2 MiB of rows: larger than one 256 KiB tile
        let data = random_vec(&mut rng, n * d);
        // pin the 128-bit backend where available so the scan cell
        // names stay stable across hosts whose auto-selection differs
        // (f32 scans dispatch to the same 128-bit kernels either way)
        let kernels =
            Kernels::with_backend(Backend::Sse2).unwrap_or_else(Kernels::select);
        let tag = kernels.backend_name();
        let tile = (256 * 1024) / (d * 4);
        for &b in &[1usize, 8, 32] {
            let queries: Vec<Vec<f32>> =
                (0..b).map(|_| random_vec(&mut rng, d)).collect();
            cells.push(cell(
                &format!("scan f32 d={d} B={b} {tag} blocked"),
                n * b,
                n * d * 4,
                || {
                    let mut acc = 0f32;
                    for tile_rows in data.chunks(tile * d) {
                        for x in &queries {
                            for row in tile_rows.chunks_exact(d) {
                                acc += kernels.sq_l2(x, row);
                            }
                        }
                    }
                    std::hint::black_box(acc);
                },
            ));
            cells.push(cell(
                &format!("scan f32 d={d} B={b} {tag} noblock"),
                n * b,
                n * d * 4,
                || {
                    let mut acc = 0f32;
                    for x in &queries {
                        for row in data.chunks_exact(d) {
                            acc += kernels.sq_l2(x, row);
                        }
                    }
                    std::hint::black_box(acc);
                },
            ));
        }
    }

    write_kernel_json(&cells);
}

/// Rich JSON for benchcmp: meta (provenance) + measurements with the
/// derived ns/distance and GB/s columns.
fn write_kernel_json(cells: &[Cell]) {
    let Ok(path) = std::env::var("AMSEARCH_BENCH_JSON") else {
        return;
    };
    let cpu = std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("model name"))
                .and_then(|l| l.split(':').nth(1))
                .map(|v| v.trim().to_string())
        })
        .unwrap_or_else(|| "unknown".to_string());
    let mut out = String::from("{\n  \"meta\": {\n");
    out.push_str("    \"schema\": 1,\n    \"bench\": \"kernels\",\n");
    out.push_str(&format!(
        "    \"arch\": {:?},\n    \"os\": {:?},\n    \"cpu\": {cpu:?},\n",
        std::env::consts::ARCH,
        std::env::consts::OS
    ));
    out.push_str("    \"harness\": \"rust-bench\"\n  },\n  \"measurements\": [\n");
    for (i, c) in cells.iter().enumerate() {
        let sep = if i + 1 == cells.len() { "" } else { "," };
        out.push_str(&format!(
            "    {{\"name\": {:?}, \"iters\": {}, \
             \"ns_per_distance\": {:.2}, \"gbps\": {:.2}}}{sep}\n",
            c.m.name, c.m.iters, c.ns_per_distance, c.gbps
        ));
    }
    out.push_str("  ]\n}\n");
    match std::fs::write(&path, out) {
        Ok(()) => println!("\nwrote {} cells to {path}", cells.len()),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
