"""AOT compile path: lower L2 graphs to HLO text + manifest for rust.

HLO *text* (NOT ``lowered.compile()`` / ``.serialize()``) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids which xla_extension 0.5.1 (what the published ``xla``
0.1.6 crate links) rejects with ``proto.id() <= INT_MAX``.  The HLO text
parser reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/README.md.

Usage (from ``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits one ``<name>.hlo.txt`` per (graph, shape-config) pair plus
``manifest.json`` describing every artifact's operand/result shapes, which
``rust/src/runtime/artifacts.rs`` consumes.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Default shape configurations built by `make artifacts`.  Each entry
# yields a class_scores and a class_distances artifact.  Keep this list
# small: the rust runtime compiles each at startup.
#   d: vector dimension, q: number of classes, b: AOT batch size,
#   k: class size for the candidate-scan graph.
DEFAULT_CONFIGS = (
    {"d": 128, "q": 64, "b": 8, "k": 256},   # quickstart / SIFT-like n=16k
    {"d": 64, "q": 32, "b": 8, "k": 512},    # dense-synthetic n=16k
)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_class_scores(d: int, q: int, b: int) -> str:
    lowered = jax.jit(model.class_scores_fn).lower(_spec((q, d, d)), _spec((b, d)))
    return to_hlo_text(lowered)


def lower_class_distances(d: int, k: int, b: int) -> str:
    lowered = jax.jit(model.class_distances_fn).lower(_spec((k, d)), _spec((b, d)))
    return to_hlo_text(lowered)


def lower_build_bank(d: int, q: int, k: int) -> str:
    lowered = jax.jit(model.build_bank_fn).lower(_spec((q, k, d)))
    return to_hlo_text(lowered)


def build_artifacts(configs, out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for cfg in configs:
        d, q, b, k = cfg["d"], cfg["q"], cfg["b"], cfg["k"]

        name = f"class_scores_d{d}_q{q}_b{b}"
        text = lower_class_scores(d, q, b)
        path = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        entries.append({
            "name": name,
            "kind": "class_scores",
            "file": path,
            "d": d, "q": q, "b": b,
            "inputs": [
                {"shape": [q, d, d], "dtype": "f32"},
                {"shape": [b, d], "dtype": "f32"},
            ],
            "outputs": [{"shape": [b, q], "dtype": "f32"}],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        })

        name = f"class_distances_d{d}_k{k}_b{b}"
        text = lower_class_distances(d, k, b)
        path = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        entries.append({
            "name": name,
            "kind": "class_distances",
            "file": path,
            "d": d, "k": k, "b": b,
            "inputs": [
                {"shape": [k, d], "dtype": "f32"},
                {"shape": [b, d], "dtype": "f32"},
            ],
            "outputs": [{"shape": [b, k], "dtype": "f32"}],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        })

        name = f"build_bank_d{d}_q{q}_k{k}"
        text = lower_build_bank(d, q, k)
        path = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        entries.append({
            "name": name,
            "kind": "build_bank",
            "file": path,
            "d": d, "q": q, "k": k, "b": 1,
            "inputs": [{"shape": [q, k, d], "dtype": "f32"}],
            "outputs": [{"shape": [q, d, d], "dtype": "f32"}],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        })
        print(f"  lowered config d={d} q={q} b={b} k={k}")

    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def parse_configs(spec: str):
    """Parse 'd=128,q=64,b=8,k=256;d=64,...' into config dicts."""
    configs = []
    for part in spec.split(";"):
        cfg = {}
        for kv in part.split(","):
            key, val = kv.split("=")
            cfg[key.strip()] = int(val)
        for key in ("d", "q", "b", "k"):
            if key not in cfg:
                raise ValueError(f"config {part!r} missing {key}")
        configs.append(cfg)
    return configs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default=None,
                    help="semicolon-separated d=..,q=..,b=..,k=.. tuples")
    args = ap.parse_args()
    configs = parse_configs(args.configs) if args.configs else DEFAULT_CONFIGS
    manifest = build_artifacts(configs, args.out_dir)
    n = len(manifest["artifacts"])
    print(f"wrote {n} artifacts + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
