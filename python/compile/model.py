"""Layer-2 JAX graphs exported to the rust runtime.

Two computations per shape configuration, both shipped as HLO text:

* ``class_scores``:   (W[q,d,d], X[B,d]) -> S[B,q]  — polls every class
  memory with every query via the L1 pallas kernel (the paper's score
  s(X^i, x0) = x0^T W_i x0).
* ``class_distances``: (V[k,d], X[B,d]) -> D[B,k]  — the in-class
  exhaustive candidate scan as a fused ||x||^2 - 2 x.v + ||v||^2 GEMM.
  XLA fuses this into a single matmul + elementwise epilogue; no custom
  kernel is warranted (its roofline IS the GEMM).

Top-p selection and final argmin run in rust: they are O(q log p) /
O(k) and dominated by the scans above; keeping them out of the graph
lets the coordinator vary p per request without recompiling.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.bank_build import build_bank
from .kernels.class_score import class_scores


def class_scores_fn(w, x):
    """Exported: batched bilinear class scores via the pallas kernel."""
    return (class_scores(w, x),)


def build_bank_fn(members):
    """Exported: stacked memory construction via the pallas kernel.

    (members[q,k,d]) -> W[q,d,d] with W_i = members_i^T members_i.
    Build-path computation: used by `amsearch` when rebuilding banks
    offline; additive, so shards of members can be built separately and
    summed.
    """
    return (build_bank(members),)


def class_distances_fn(v, x):
    """Exported: squared-L2 candidate scan, one GEMM + epilogue.

    D[b, j] = ||x_b||^2 - 2 <x_b, v_j> + ||v_j||^2
    """
    x = x.astype(jnp.float32)
    v = v.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)      # [B, 1]
    v2 = jnp.sum(v * v, axis=1)[None, :]            # [1, k]
    cross = x @ v.T                                 # [B, k] — the GEMM
    return (x2 - 2.0 * cross + v2,)
