"""Pure-jnp oracles for the Layer-1 kernels and Layer-2 graphs.

These are the correctness ground truth: slow, obvious, no tiling.  Every
pallas kernel and exported graph is pytest-checked against these.
"""

from __future__ import annotations

import jax.numpy as jnp


def class_scores_ref(w, x):
    """scores[b, i] = x_b^T W_i x_b, the naive einsum."""
    return jnp.einsum("bl,qlm,bm->bq", x, w, x)


def class_scores_expanded_ref(vectors_per_class, x):
    """Score from raw class members: sum_mu <x, x_mu>^2.

    vectors_per_class: [q, k, d]; x: [B, d] -> [B, q].
    Identity check that the memory matrix loses nothing for scoring.
    """
    dots = jnp.einsum("bd,qkd->bqk", x, vectors_per_class)
    return jnp.sum(dots * dots, axis=-1)


def class_distances_ref(v, x):
    """Squared L2 distances, naive: D[b, j] = ||x_b - v_j||^2."""
    diff = x[:, None, :] - v[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def build_memory_ref(vectors):
    """Sum-of-outer-products memory: W = sum_mu x_mu x_mu^T.  [k,d]->[d,d]."""
    return jnp.einsum("kl,km->lm", vectors, vectors)
