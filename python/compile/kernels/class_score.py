"""Layer-1 Pallas kernel: batched associative-memory class scoring.

The paper's hot spot is polling every class memory with the query:

    scores[b, i] = x_b^T W_i x_b      W: [q, d, d], X: [B, d] -> S: [B, q]

This is a batched symmetric bilinear form.  On TPU it is MXU-shaped: for a
tile of TQ memories and TB queries we compute one [TQ*d, d] x [d, TB]
matmul (the W_i @ x_b matvecs for the whole tile, fused into a single
systolic-array pass) followed by a VPU multiply-reduce against the queries.

The HBM<->VMEM schedule is expressed with BlockSpecs over a (q/TQ, B/TB)
grid: each grid step stages a [TQ, d, d] slab of memories and a [TB, d]
slab of queries into VMEM.  With the default d=128, TQ=8, TB=8 the W slab
is 512 KiB and the intermediates ~8 KiB, leaving ample VMEM headroom for
the implicit double buffering of the pallas pipeline.

``interpret=True`` is mandatory on this image: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO ops
that both the python tests and the rust runtime can run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  TQ*d*d*4 bytes must fit comfortably in VMEM
# (d=128 -> 512 KiB, d=256 -> 2 MiB).  Both are clamped to the actual
# (q, B) at call time.
DEFAULT_TQ = 8
DEFAULT_TB = 8


def _score_kernel(w_ref, x_ref, o_ref):
    """One grid step: scores for a [TQ] x [TB] tile of (class, query) pairs.

    w_ref: [TQ, d, d] VMEM slab of memories
    x_ref: [TB, d]    VMEM slab of queries
    o_ref: [TB, TQ]   output tile
    """
    w = w_ref[...]
    x = x_ref[...]
    tq, d, _ = w.shape
    # All TQ matvecs W_i @ x_b as ONE [TQ*d, d] x [d, TB] matmul: this is
    # the MXU pass.  preferred_element_type pins f32 accumulation.
    wx = jax.lax.dot_general(
        w.reshape(tq * d, d),
        x,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [TQ*d, TB]
    wx = wx.reshape(tq, d, x.shape[0])
    # VPU reduce: s[i, b] = sum_l x[b, l] * (W_i x_b)[l]
    s = jnp.sum(wx * x.T[None, :, :], axis=1)  # [TQ, TB]
    o_ref[...] = s.T.astype(o_ref.dtype)


def _pick_tile(n: int, pref: int) -> int:
    """Largest divisor of ``n`` that is <= pref (so the grid tiles exactly)."""
    t = min(pref, n)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("tq", "tb"))
def class_scores(w: jax.Array, x: jax.Array, *, tq: int = DEFAULT_TQ,
                 tb: int = DEFAULT_TB) -> jax.Array:
    """Score every class memory against every query.

    Args:
      w: [q, d, d] float32 stacked class memories (symmetric, but symmetry
         is not assumed).
      x: [B, d] float32 queries.
      tq/tb: preferred tile sizes along classes / batch.

    Returns:
      [B, q] float32 scores, scores[b, i] = x_b^T W_i x_b.
    """
    q, d, d2 = w.shape
    b, dx = x.shape
    if d != d2 or d != dx:
        raise ValueError(f"shape mismatch: w={w.shape} x={x.shape}")
    tq = _pick_tile(q, tq)
    tb = _pick_tile(b, tb)
    grid = (q // tq, b // tb)
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tq, d, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tb, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tb, tq), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((b, q), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(w.astype(jnp.float32), x.astype(jnp.float32))
