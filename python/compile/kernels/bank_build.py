"""Layer-1 Pallas kernel: batched associative-memory construction.

The paper's build-time compute is the sum-of-outer-products memory

    W_i = sum_mu x^mu (x^mu)^T        X: [q, k, d] -> W: [q, d, d]

per class i.  As a contraction this is one [d, k] x [k, d] matmul per
class (X_i^T @ X_i) — MXU-shaped, f32-accumulated.  The grid tiles the
class axis; each step stages a [TQ, k, d] member slab into VMEM and
emits a [TQ, d, d] weight slab.  For the default build shapes
(k=256, d=128, TQ=2) the member slab is 256 KiB and the output 128 KiB.

``interpret=True`` for the same reason as class_score.py: the CPU PJRT
plugin cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TQ = 2


def _build_kernel(x_ref, w_ref):
    """One grid step: memories for a [TQ] tile of classes.

    x_ref: [TQ, k, d] VMEM slab of class members
    w_ref: [TQ, d, d] output weight slab
    """
    x = x_ref[...]
    tq, _k, _d = x.shape
    # one X^T X matmul per class in the tile; MXU with f32 accumulation
    for i in range(tq):  # static unroll: tq is a compile-time constant
        xi = x[i]
        w_ref[i, :, :] = jax.lax.dot_general(
            xi,
            xi,
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(w_ref.dtype)


def _pick_tile(n: int, pref: int) -> int:
    t = min(pref, n)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("tq",))
def build_bank(members: jax.Array, *, tq: int = DEFAULT_TQ) -> jax.Array:
    """Build all q class memories from stacked members.

    Args:
      members: [q, k, d] float32 class member matrix.
      tq: preferred class-tile size.

    Returns:
      [q, d, d] float32 stacked memories, W[i] = members[i]^T members[i].
    """
    q, k, d = members.shape
    tq = _pick_tile(q, tq)
    return pl.pallas_call(
        _build_kernel,
        grid=(q // tq,),
        in_specs=[pl.BlockSpec((tq, k, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((tq, d, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((q, d, d), jnp.float32),
        interpret=True,
    )(members.astype(jnp.float32))
