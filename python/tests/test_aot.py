"""AOT path: HLO text emission, manifest contents, config parsing."""

import json
import os

import pytest

from compile import aot


def test_parse_configs():
    cfgs = aot.parse_configs("d=16,q=4,b=2,k=8;d=8,q=2,b=1,k=4")
    assert cfgs == [
        {"d": 16, "q": 4, "b": 2, "k": 8},
        {"d": 8, "q": 2, "b": 1, "k": 4},
    ]


def test_parse_configs_missing_key():
    with pytest.raises(ValueError):
        aot.parse_configs("d=16,q=4,b=2")


def test_lower_class_scores_is_hlo_text():
    text = aot.lower_class_scores(d=8, q=4, b=2)
    assert "HloModule" in text
    assert "f32[4,8,8]" in text
    assert "f32[2,8]" in text
    # return_tuple=True => root is a tuple of the single output
    assert "f32[2,4]" in text


def test_lower_class_distances_is_hlo_text():
    text = aot.lower_class_distances(d=8, k=16, b=2)
    assert "HloModule" in text
    assert "f32[16,8]" in text
    assert "f32[2,16]" in text


def test_build_artifacts_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build_artifacts(
        [{"d": 8, "q": 4, "b": 2, "k": 8}], out)
    assert manifest["version"] == 1
    arts = manifest["artifacts"]
    assert len(arts) == 3
    kinds = {a["kind"] for a in arts}
    assert kinds == {"class_scores", "class_distances", "build_bank"}
    for a in arts:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path)
        with open(path) as f:
            assert "HloModule" in f.read()
        assert len(a["sha256"]) == 64
    # manifest.json round-trips
    with open(os.path.join(out, "manifest.json")) as f:
        loaded = json.load(f)
    assert loaded == manifest


def test_scores_artifact_shapes_in_manifest(tmp_path):
    out = str(tmp_path / "a2")
    manifest = aot.build_artifacts([{"d": 8, "q": 4, "b": 2, "k": 8}], out)
    scores = [a for a in manifest["artifacts"] if a["kind"] == "class_scores"][0]
    assert scores["inputs"][0]["shape"] == [4, 8, 8]
    assert scores["inputs"][1]["shape"] == [2, 8]
    assert scores["outputs"][0]["shape"] == [2, 4]
    dists = [a for a in manifest["artifacts"] if a["kind"] == "class_distances"][0]
    assert dists["inputs"][0]["shape"] == [8, 8]
    assert dists["outputs"][0]["shape"] == [2, 8]
    bank = [a for a in manifest["artifacts"] if a["kind"] == "build_bank"][0]
    assert bank["inputs"][0]["shape"] == [4, 8, 8]
    assert bank["outputs"][0]["shape"] == [4, 8, 8]
