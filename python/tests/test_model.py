"""Layer-2 graph contracts: shapes, numerics vs references, fusion sanity."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def test_class_scores_fn_matches_ref():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((8, 32, 32)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((4, 32)).astype(np.float32))
    (got,) = model.class_scores_fn(w, x)
    want = ref.class_scores_ref(w, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_class_distances_fn_matches_ref():
    rng = np.random.default_rng(1)
    v = jnp.asarray(rng.standard_normal((50, 24)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((7, 24)).astype(np.float32))
    (got,) = model.class_distances_fn(v, x)
    want = ref.class_distances_ref(v, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_class_distances_self_is_zero():
    rng = np.random.default_rng(2)
    v = jnp.asarray(rng.standard_normal((5, 16)).astype(np.float32))
    (d,) = model.class_distances_fn(v, v)
    diag = np.diag(np.asarray(d))
    np.testing.assert_allclose(diag, np.zeros(5), atol=1e-3)


def test_class_distances_argmin_is_true_nn():
    rng = np.random.default_rng(3)
    v = rng.standard_normal((200, 32)).astype(np.float32)
    x = rng.standard_normal((10, 32)).astype(np.float32)
    (d,) = model.class_distances_fn(jnp.asarray(v), jnp.asarray(x))
    got = np.argmin(np.asarray(d), axis=1)
    want = np.argmin(((x[:, None, :] - v[None, :, :]) ** 2).sum(-1), axis=1)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 64),
    d=st.sampled_from([2, 8, 17, 32]),
    b=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_class_distances_hypothesis(k, d, b, seed):
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.standard_normal((k, d)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((b, d)).astype(np.float32))
    (got,) = model.class_distances_fn(v, x)
    want = ref.class_distances_ref(v, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
    assert got.shape == (b, k)


def test_class_distances_lowered_has_single_dot():
    """Fusion sanity: the candidate scan lowers to exactly one dot
    (the GEMM); the rest is elementwise epilogue."""
    spec = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    xspec = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    lowered = jax.jit(model.class_distances_fn).lower(spec, xspec)
    hlo = lowered.compiler_ir("hlo").as_hlo_text()
    n_dots = hlo.count(" dot(")
    assert n_dots == 1, f"expected 1 dot, got {n_dots}:\n{hlo}"
