"""Pallas class-score kernel vs pure-jnp reference — the CORE correctness
signal for Layer 1.

Covers fixed shape grids, the expanded-members identity, degenerate tiles,
dtype promotion, and a hypothesis sweep over (d, q, B) and value
distributions.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.class_score import class_scores, _pick_tile
from compile.kernels import ref


def _rand(shape, rng, kind="normal"):
    if kind == "normal":
        return rng.standard_normal(shape).astype(np.float32)
    if kind == "pm1":
        return rng.choice([-1.0, 1.0], size=shape).astype(np.float32)
    if kind == "sparse01":
        return (rng.random(shape) < 0.06).astype(np.float32)
    raise ValueError(kind)


@pytest.mark.parametrize("q,d,b", [
    (1, 4, 1),
    (2, 8, 3),
    (8, 16, 8),
    (10, 32, 5),     # q not a multiple of default TQ
    (64, 128, 8),    # the AOT quickstart shape
    (7, 64, 2),      # prime q
])
@pytest.mark.parametrize("kind", ["normal", "pm1", "sparse01"])
def test_kernel_matches_ref(q, d, b, kind):
    rng = np.random.default_rng(q * 1000 + d + b)
    w = _rand((q, d, d), rng, kind)
    # symmetrize like a real memory (kernel must not rely on it, but this
    # is the production distribution)
    w = w + np.swapaxes(w, 1, 2)
    x = _rand((b, d), rng, kind)
    got = class_scores(jnp.asarray(w), jnp.asarray(x))
    want = ref.class_scores_ref(jnp.asarray(w), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_kernel_asymmetric_memory():
    """Kernel must compute x^T W x exactly, without assuming symmetry."""
    rng = np.random.default_rng(0)
    w = rng.standard_normal((4, 16, 16)).astype(np.float32)
    x = rng.standard_normal((2, 16)).astype(np.float32)
    got = np.asarray(class_scores(jnp.asarray(w), jnp.asarray(x)))
    want = np.einsum("bl,qlm,bm->bq", x, w, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_kernel_equals_expanded_members():
    """x^T (sum_mu x_mu x_mu^T) x == sum_mu <x, x_mu>^2 — the associative
    memory loses nothing for class scoring."""
    rng = np.random.default_rng(1)
    q, k, d, b = 6, 10, 24, 4
    members = rng.choice([-1.0, 1.0], size=(q, k, d)).astype(np.float32)
    w = np.einsum("qkl,qkm->qlm", members, members)
    x = rng.choice([-1.0, 1.0], size=(b, d)).astype(np.float32)
    got = np.asarray(class_scores(jnp.asarray(w), jnp.asarray(x)))
    want = np.asarray(ref.class_scores_expanded_ref(
        jnp.asarray(members), jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_kernel_query_in_class_dominates():
    """Sanity on the paper's mechanism: the class containing the query
    scores highest (overwhelmingly, for d >> per-class crosstalk)."""
    rng = np.random.default_rng(2)
    q, k, d = 8, 4, 256
    members = rng.choice([-1.0, 1.0], size=(q, k, d)).astype(np.float32)
    w = np.einsum("qkl,qkm->qlm", members, members)
    x = members[3, 0][None, :]  # query = a stored pattern of class 3
    s = np.asarray(class_scores(jnp.asarray(w), jnp.asarray(x)))[0]
    assert int(np.argmax(s)) == 3


def test_pick_tile():
    assert _pick_tile(64, 8) == 8
    assert _pick_tile(10, 8) == 5
    assert _pick_tile(7, 8) == 7
    assert _pick_tile(1, 8) == 1
    assert _pick_tile(12, 8) == 6
    for n in range(1, 40):
        t = _pick_tile(n, 8)
        assert n % t == 0 and 1 <= t <= 8


def test_kernel_shape_mismatch_raises():
    w = jnp.zeros((2, 8, 8))
    x = jnp.zeros((1, 9))
    with pytest.raises(ValueError):
        class_scores(w, x)


def test_kernel_explicit_tiles():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.standard_normal((12, 16, 16)).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((6, 16)).astype(np.float32))
    want = np.asarray(ref.class_scores_ref(w, x))
    for tq in (1, 2, 3, 4, 6, 12):
        for tb in (1, 2, 3, 6):
            got = np.asarray(class_scores(w, x, tq=tq, tb=tb))
            np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(
    q=st.integers(1, 24),
    d=st.sampled_from([4, 8, 16, 32, 48, 64]),
    b=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
    kind=st.sampled_from(["normal", "pm1", "sparse01"]),
)
def test_kernel_hypothesis_sweep(q, d, b, seed, kind):
    rng = np.random.default_rng(seed)
    w = _rand((q, d, d), rng, kind)
    x = _rand((b, d), rng, kind)
    got = np.asarray(class_scores(jnp.asarray(w), jnp.asarray(x)))
    want = np.asarray(ref.class_scores_ref(jnp.asarray(w), jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_kernel_bf16_inputs_promote():
    """bf16 operands are accepted and accumulated in f32."""
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.standard_normal((4, 32, 32)), dtype=jnp.bfloat16)
    x = jnp.asarray(rng.standard_normal((2, 32)), dtype=jnp.bfloat16)
    got = class_scores(w, x)
    assert got.dtype == jnp.float32
    want = ref.class_scores_ref(w.astype(jnp.float32), x.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-2, atol=3e-1)


def test_kernel_zero_memory():
    got = class_scores(jnp.zeros((3, 8, 8)), jnp.ones((2, 8)))
    np.testing.assert_array_equal(np.asarray(got), np.zeros((2, 3)))
