"""Pallas bank-build kernel vs reference: W_i = X_i^T X_i."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.bank_build import build_bank, _pick_tile
from compile.kernels import ref


@pytest.mark.parametrize("q,k,d", [
    (1, 1, 4),
    (2, 8, 16),
    (3, 5, 7),       # odd everything
    (8, 32, 32),
    (5, 16, 24),     # q not a multiple of TQ
])
@pytest.mark.parametrize("kind", ["normal", "pm1", "sparse01"])
def test_build_matches_ref(q, k, d, kind):
    rng = np.random.default_rng(q * 100 + k + d)
    if kind == "normal":
        m = rng.standard_normal((q, k, d)).astype(np.float32)
    elif kind == "pm1":
        m = rng.choice([-1.0, 1.0], size=(q, k, d)).astype(np.float32)
    else:
        m = (rng.random((q, k, d)) < 0.1).astype(np.float32)
    got = np.asarray(build_bank(jnp.asarray(m)))
    want = np.stack([np.asarray(ref.build_memory_ref(jnp.asarray(mi))) for mi in m])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_built_bank_scores_consistently():
    """build_bank composed with class_scores == expanded-members oracle."""
    from compile.kernels.class_score import class_scores
    rng = np.random.default_rng(1)
    q, k, d, b = 4, 12, 16, 3
    m = rng.choice([-1.0, 1.0], size=(q, k, d)).astype(np.float32)
    x = rng.choice([-1.0, 1.0], size=(b, d)).astype(np.float32)
    w = build_bank(jnp.asarray(m))
    got = np.asarray(class_scores(w, jnp.asarray(x)))
    want = np.asarray(ref.class_scores_expanded_ref(jnp.asarray(m), jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_bank_is_symmetric_psd_diag():
    rng = np.random.default_rng(2)
    m = rng.standard_normal((2, 6, 8)).astype(np.float32)
    w = np.asarray(build_bank(jnp.asarray(m)))
    for wi in w:
        np.testing.assert_allclose(wi, wi.T, rtol=1e-5, atol=1e-5)
        assert np.all(np.diag(wi) >= -1e-5)  # diag = sum of squares


def test_additivity_shards():
    """Banks are additive: building in shards and summing == full build."""
    rng = np.random.default_rng(3)
    q, k, d = 2, 10, 8
    m = rng.standard_normal((q, k, d)).astype(np.float32)
    full = np.asarray(build_bank(jnp.asarray(m)))
    part1 = np.asarray(build_bank(jnp.asarray(m[:, :4, :])))
    part2 = np.asarray(build_bank(jnp.asarray(m[:, 4:, :])))
    np.testing.assert_allclose(full, part1 + part2, rtol=1e-5, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    q=st.integers(1, 8),
    k=st.integers(1, 24),
    d=st.sampled_from([2, 4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_build_hypothesis(q, k, d, seed):
    rng = np.random.default_rng(seed)
    m = rng.standard_normal((q, k, d)).astype(np.float32)
    got = np.asarray(build_bank(jnp.asarray(m)))
    want = np.einsum("qkl,qkm->qlm", m, m)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
    assert got.shape == (q, d, d)


def test_pick_tile_divides():
    for n in range(1, 20):
        t = _pick_tile(n, 2)
        assert n % t == 0
